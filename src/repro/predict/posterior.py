"""Batch expert-selection prediction (paper §III-B, Eqs. 1-2).

The posterior of expert N_{e,i} given only the known feature f1' of a new
token marginalizes the unknown position f2 and attention ID f3 through the
profiled joint counts. Expanding Eq. (1), the P'(f2) / P*(f1',f2) factors
cancel between the inner integrand and the outer weight, leaving

    P(N_{e,i} | f1')  ∝  sum_{f2, f3} count(f1', f2, f3, e, i) * P'(f3)

with P'(f3) approximated by the dataset frequency of token f3 (the paper's
stated approximation: the attention ID is itself a token ID). Prediction is
maximum-a-posteriori (Eq. 2), extended to top-k.

``mode="lina"`` reproduces the Lina baseline [USENIX ATC'23]: token-ID-only
posterior, i.e. count(f1', e, i) with no attention-frequency weighting.

``fit()`` additionally compiles the per-(layer, f1) posterior dict into a
dense ``(L, V, E)`` tensor so ``predict`` / ``predict_demand`` run as one
gather + one batched argsort instead of the historical per-layer,
per-unique-token Python loops. The dense rows hold EXACTLY the floats
``posterior()`` returns (same divisions, same fallback rows), so the
vectorized MAP path is bit-identical to the loop path — pinned by
``tests/test_predict_streaming.py`` against the reference implementations
kept at the bottom of this module. Geometries whose dense tensor would
exceed ``DENSE_POSTERIOR_LIMIT`` elements skip compilation and fall back
to the reference loops.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.table import KVTable, unpack_key

# (L * V * E) above this never materializes the dense posterior tensor
# (full-vocab models): the reference per-row loops serve instead.
DENSE_POSTERIOR_LIMIT = 1 << 24


def _normalized_rows(raw: np.ndarray, prior: np.ndarray) -> np.ndarray:
    """(L, V, E) raw posterior rows -> normalized, with empty rows falling
    back to the per-layer prior — the same floats ``posterior()`` yields:
    present rows divide by their own ``row.sum()``, absent/zero rows divide
    the prior row by ``prior.sum()`` (always > 0 with the Laplace ones)."""
    sums = raw.sum(axis=-1)                      # (L, V)
    dense = raw / np.where(sums == 0.0, 1.0, sums)[..., None]
    prior_rows = prior / prior.sum(axis=-1, keepdims=True)
    empty_l, empty_v = np.nonzero(sums == 0.0)
    dense[empty_l, empty_v] = prior_rows[empty_l]
    return dense


# --- shared dense-tensor prediction kernels --------------------------------
# One implementation serves ExpertPredictor and OnlinePredictor (the two
# must never diverge). Token ids OUTSIDE [0, V) gather the normalized
# per-layer prior row — exactly the dict-lookup fallback ``posterior()``
# takes for an unseen key, so the dense path stays bit-identical to the
# reference loops even on unsanitized ids.

def _gather_rows(dense: np.ndarray, prior: np.ndarray, layer: int,
                 uniq: np.ndarray) -> np.ndarray:
    V = dense.shape[1]
    rows = dense[layer, np.clip(uniq, 0, V - 1)]
    bad = (uniq < 0) | (uniq >= V)
    if bad.any():
        rows[bad] = prior[layer] / prior[layer].sum()
    return rows


def _gather_rows_all_layers(dense: np.ndarray, prior: np.ndarray,
                            uniq: np.ndarray) -> np.ndarray:
    V = dense.shape[1]
    rows = dense[:, np.clip(uniq, 0, V - 1), :]      # (L, U, E)
    bad = (uniq < 0) | (uniq >= V)
    if bad.any():
        rows[:, bad, :] = (prior / prior.sum(axis=-1,
                                             keepdims=True))[:, None, :]
    return rows


def dense_predict(dense: np.ndarray, prior: np.ndarray, layer: int,
                  token_ids: np.ndarray, k: int) -> np.ndarray:
    """Eq. 2 top-k over dense posterior rows: (N,) ids -> (N, k)."""
    token_ids = np.asarray(token_ids).ravel()
    uniq, inv = np.unique(token_ids, return_inverse=True)
    rows = _gather_rows(dense, prior, layer, uniq)
    return np.argsort(-rows, axis=-1)[:, :k][inv]


def dense_predict_layers(dense: np.ndarray, prior: np.ndarray,
                         token_ids: np.ndarray, k: int) -> np.ndarray:
    """All layers at once: (N,) ids -> (L, N, k) MAP experts."""
    toks = np.asarray(token_ids).ravel()
    uniq, inv = np.unique(toks, return_inverse=True)
    rows = _gather_rows_all_layers(dense, prior, uniq)
    return np.argsort(-rows, axis=-1)[..., :k][:, inv, :]


def dense_predict_demand(dense: np.ndarray, prior: np.ndarray,
                         tokens: np.ndarray, k: int,
                         mode: str) -> np.ndarray:
    """Predicted (L, E) demand in one batched pass over the tensor."""
    L, _, E = dense.shape
    flat = np.asarray(tokens).ravel()
    uniq, cnt = np.unique(flat, return_counts=True)
    rows = _gather_rows_all_layers(dense, prior, uniq)   # (L, U, E)
    if mode == "expected":
        return k * np.einsum('u,lue->le', cnt.astype(float), rows)
    demand = np.zeros((L, E))
    tops = np.argsort(-rows, axis=-1)[..., :k]           # (L, U, k)
    for layer in range(L):
        np.add.at(demand[layer], tops[layer],
                  np.broadcast_to(cnt[:, None].astype(float),
                                  tops[layer].shape))
    return demand


@dataclass
class ExpertPredictor:
    table: KVTable
    mode: str = "full"          # "full" (ours) | "lina" (token-ID only)
    top_k: int = 1
    _post: Dict[Tuple[int, int], np.ndarray] = field(default_factory=dict)
    _prior: Optional[np.ndarray] = None     # (L, E) per-layer expert prior
    _dense: Optional[np.ndarray] = None     # (L, V, E) normalized posterior

    # ------------------------------------------------------------------ fit
    def fit(self) -> "ExpertPredictor":
        """Compile per-(layer, f1) posteriors from the current table."""
        keys, vals = self.table.entries()
        L, E = self.table.num_layers, self.table.num_experts
        self._post = {}
        self._prior = np.ones((L, E))       # Laplace prior
        if len(keys) == 0:
            self._compile_dense()
            return self
        layer, f1, f2, f3, expert = unpack_key(keys)
        if self.mode == "full":
            tf = self.table.token_prob
            w = vals * np.maximum(tf[np.clip(f3, 0, len(tf) - 1)], 1e-12)
        else:
            w = vals.astype(float)
        # group by (layer, f1, expert)
        group = (layer * self.table.vocab_size + f1) * E + expert
        uniq, inv = np.unique(group, return_inverse=True)
        agg = np.zeros(len(uniq))
        np.add.at(agg, inv, w)
        u_layer = uniq // (self.table.vocab_size * E)
        u_f1 = (uniq // E) % self.table.vocab_size
        u_e = uniq % E
        order = np.lexsort((u_e, u_f1, u_layer))
        u_layer, u_f1, u_e, agg = (a[order] for a in
                                   (u_layer, u_f1, u_e, agg))
        lf = u_layer * self.table.vocab_size + u_f1
        starts = np.searchsorted(lf, np.unique(lf))
        bounds = np.append(starts, len(lf))
        for s, t in zip(bounds[:-1], bounds[1:]):
            li, fi = int(u_layer[s]), int(u_f1[s])
            post = np.zeros(E)
            post[u_e[s:t]] = agg[s:t]
            self._post[(li, fi)] = post
            self._prior[li] += post
        self._compile_dense()
        return self

    def _compile_dense(self) -> None:
        L, E = self.table.num_layers, self.table.num_experts
        V = self.table.vocab_size
        if L * V * E > DENSE_POSTERIOR_LIMIT:
            self._dense = None
            return
        raw = np.zeros((L, V, E))
        for (li, fi), post in self._post.items():
            raw[li, fi] = post
        self._dense = _normalized_rows(raw, self._prior)

    # -------------------------------------------------------------- predict
    def posterior(self, layer: int, token_id: int) -> np.ndarray:
        assert self._prior is not None, "call fit() first"
        p = self._post.get((layer, int(token_id)))
        if p is None or p.sum() == 0:
            p = self._prior[layer]
        s = p.sum()
        return p / s if s > 0 else np.full(len(p), 1.0 / len(p))

    def posteriors(self) -> np.ndarray:
        """The dense normalized ``(L, V, E)`` posterior tensor (each row a
        distribution over experts). Requires a geometry under
        ``DENSE_POSTERIOR_LIMIT``."""
        assert self._prior is not None, "call fit() first"
        if self._dense is None:
            raise ValueError(
                "posterior tensor would exceed DENSE_POSTERIOR_LIMIT "
                f"({self.table.num_layers}x{self.table.vocab_size}x"
                f"{self.table.num_experts}); use posterior(layer, token)")
        return self._dense

    def predict(self, layer: int, token_ids: np.ndarray,
                k: Optional[int] = None) -> np.ndarray:
        """Eq. 2 (top-k): (N,) token ids -> (N, k) predicted experts."""
        k = k or self.top_k
        if self._dense is None:
            return predict_reference(self, layer, token_ids, k)
        return dense_predict(self._dense, self._prior, layer, token_ids, k)

    def predict_demand(self, tokens: np.ndarray, k: Optional[int] = None,
                       mode: str = "map") -> np.ndarray:
        """Predicted per-expert token counts d_{e,i}: (L, E).

        ``mode="map"`` assigns every token instance to its MAP experts
        (Eq. 2, the paper's method) — one batched argsort over the dense
        tensor, exactly equal to the per-token loop (integer-count
        accumulation is order-free). ``mode="expected"`` accumulates the
        full posterior instead — a beyond-paper improvement that captures
        positionally-spread routing (EXPERIMENTS.md §Repro ablation) —
        as one einsum over the gathered rows (equal to the loop within
        float-summation-order tolerance).
        """
        k = k or self.top_k
        if self._dense is None:
            return predict_demand_reference(self, tokens, k=k, mode=mode)
        return dense_predict_demand(self._dense, self._prior, tokens, k,
                                    mode)

    # --------------------------------------------------------------- metrics
    def prediction_difference(self, demand_pred: np.ndarray,
                              demand_real: np.ndarray) -> float:
        """Fig. 10 metric: mean |real - predicted| tokens per expert
        (delegates to :func:`repro.predict.calibration
        .prediction_difference`, kept as a method for compatibility)."""
        from repro.predict.calibration import prediction_difference
        return prediction_difference(demand_pred, demand_real)


# ---------------------------------------------------------------------------
# Reference (pre-vectorization) implementations. These are the PR-4 hot-path
# loops, kept verbatim as the differential oracle for the vectorized paths
# (tests/test_predict_streaming.py) and as the fallback for geometries too
# large for the dense tensor; benchmarks/fig10_prediction.py times the gap.
# ---------------------------------------------------------------------------

def predict_reference(pred: ExpertPredictor, layer: int,
                      token_ids: np.ndarray,
                      k: Optional[int] = None) -> np.ndarray:
    """Per-unique-token loop of the historical ``predict``."""
    k = k or pred.top_k
    token_ids = np.asarray(token_ids).ravel()
    uniq, inv = np.unique(token_ids, return_inverse=True)
    tops = np.stack([
        np.argsort(-pred.posterior(layer, t))[:k] for t in uniq])
    return tops[inv]


def predict_demand_reference(pred: ExpertPredictor, tokens: np.ndarray,
                             k: Optional[int] = None,
                             mode: str = "map") -> np.ndarray:
    """Per-layer, per-unique-token loop of the historical
    ``predict_demand``."""
    k = k or pred.top_k
    L, E = pred.table.num_layers, pred.table.num_experts
    demand = np.zeros((L, E))
    flat = np.asarray(tokens).ravel()
    uniq, cnt = np.unique(flat, return_counts=True)
    for layer in range(L):
        if mode == "expected":
            for u, c in zip(uniq, cnt):
                demand[layer] += c * k * pred.posterior(layer, int(u))
        else:
            rows = np.stack([np.argsort(-pred.posterior(layer, int(u)))[:k]
                             for u in uniq])
            for row, c in zip(rows, cnt):
                demand[layer, row] += c
    return demand
