"""Trace generation for serverless MoE workloads (arrivals, drift, replay).

Pure numpy (no JAX): importable by the simulator, benchmarks, and tests
without model warmup. See :mod:`repro.traces.generators` for the model.
"""
from repro.traces.generators import (Trace, TraceRequest, TraceWindow,
                                     bursty_arrivals, demand_trace,
                                     diurnal_arrivals, drift_popularity,
                                     poisson_arrivals, replay_telemetry,
                                     request_trace, zipf_popularity,
                                     zipf_routing)
from repro.traces.tenancy import (Tenant, TenantSLO,
                                  align_tenant_windows,
                                  mixed_tenant_pair)

__all__ = [
    "Trace", "TraceRequest", "TraceWindow",
    "Tenant", "TenantSLO", "align_tenant_windows", "mixed_tenant_pair",
    "poisson_arrivals", "bursty_arrivals", "diurnal_arrivals",
    "zipf_popularity", "drift_popularity", "zipf_routing",
    "demand_trace", "replay_telemetry", "request_trace",
]
