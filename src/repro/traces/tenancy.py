"""Tenant specifications: who shares the fleet, and on what terms.

A serverless platform's whole value is consolidating many tenants onto
one warm container fleet (FaaSMoE in PAPERS.md); this module gives the
planner and simulator a first-class vocabulary for that. A
:class:`Tenant` binds a name to a demand :class:`~repro.traces.Trace`
and a :class:`TenantSLO` — either **latency-bound** (a p99 per-window
latency target the shared plan must respect: the planner folds the
tightest target into the joint ``t_limit_s``) or **cost-bound** (no
latency constraint; the tenant rides whatever consolidation yields).

Pure numpy, no JAX — importable by the simulator and benchmarks.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

import numpy as np

from .generators import Trace, TraceWindow, bursty_arrivals, \
    demand_trace, diurnal_arrivals, zipf_popularity


@dataclass(frozen=True)
class TenantSLO:
    """A tenant's service-level objective.

    ``kind`` is ``"latency"`` (p99 per-window latency must stay under
    ``p99_target_s``) or ``"cost"`` (cost-minimizing best-effort; no
    latency bound). ``priority`` orders admission in the serving
    engine's fair-share scheduler (higher first); ``weight`` scales the
    tenant's fair share of slot throughput.
    """

    kind: str = "cost"
    p99_target_s: Optional[float] = None
    priority: int = 0
    weight: float = 1.0

    def __post_init__(self):
        if self.kind not in ("latency", "cost"):
            raise ValueError(f"unknown SLO kind {self.kind!r}")
        if self.kind == "latency" and (
                self.p99_target_s is None or self.p99_target_s <= 0):
            raise ValueError(
                "latency-bound SLO needs a positive p99_target_s")
        if self.weight <= 0:
            raise ValueError("SLO weight must be positive")


@dataclass
class Tenant:
    """One tenant of the shared fleet: a named trace plus its SLO."""

    name: str
    trace: Trace
    slo: TenantSLO = field(default_factory=TenantSLO)

    def __post_init__(self):
        if not self.name:
            raise ValueError("tenant needs a non-empty name")

    @property
    def num_tokens(self) -> int:
        return self.trace.num_tokens

    def total_demand(self) -> np.ndarray:
        return self.trace.total_demand()


def align_tenant_windows(tenants: Sequence[Tenant]
                         ) -> List[List[TraceWindow]]:
    """Align tenants' traces on a common window axis.

    Returns one list per window index; shorter traces are padded with
    zero-demand windows (shape taken from the tenant's own trace) so
    every window has exactly one entry per tenant, in tenant order.
    """
    if not tenants:
        raise ValueError("no tenants")
    names = [t.name for t in tenants]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate tenant names: {names}")
    shapes = {t.name: t.trace.windows[0].demand.shape for t in tenants
              if t.trace.windows}
    if len(set(shapes.values())) > 1:
        raise ValueError(f"tenant traces disagree on (L, E): {shapes}")
    n = max(len(t.trace) for t in tenants)
    shape = next(iter(shapes.values()))
    out: List[List[TraceWindow]] = []
    for i in range(n):
        row = []
        for t in tenants:
            if i < len(t.trace):
                row.append(t.trace.windows[i])
            else:
                row.append(TraceWindow(demand=np.zeros(shape),
                                       num_tokens=0,
                                       t_start_s=float(i)))
        out.append(row)
    return out


def mixed_tenant_pair(num_layers: int, num_experts: int, *,
                      steps: int = 12, rate: float = 3.0,
                      tokens_per_request: int = 64,
                      p99_target_s: float = 60.0,
                      seed: int = 0) -> Tuple[Tenant, Tenant]:
    """The ISSUE's canonical mixed pair: a bursty latency-bound tenant
    and a diurnal cost-bound one, with distinct Zipf popularity
    profiles (seeded independently so their hot experts differ — the
    regime where a shared pool wins by statistical multiplexing: their
    peaks do not coincide, so the pooled fleet is smaller than the sum
    of per-tenant fleets)."""
    burst = demand_trace(
        bursty_arrivals(rate, steps, seed=seed),
        zipf_popularity(num_layers, num_experts, seed=seed),
        tokens_per_request=tokens_per_request)
    slow = demand_trace(
        diurnal_arrivals(rate, steps, period=steps, seed=seed + 1),
        zipf_popularity(num_layers, num_experts, seed=seed + 1),
        tokens_per_request=tokens_per_request)
    return (
        Tenant("bursty", burst,
               TenantSLO(kind="latency", p99_target_s=p99_target_s,
                         priority=1)),
        Tenant("diurnal", slow, TenantSLO(kind="cost")),
    )
