"""Workload traces: arrival processes, expert-popularity drift, replay.

The paper evaluates one-shot batches; real serverless MoE traffic is
bursty, diurnal, and non-stationary (Remoe / FaaSMoE in PAPERS.md). This
module generates the traffic the planner must survive, in two shapes:

* **demand traces** (:class:`Trace` of :class:`TraceWindow`) — a sequence
  of (L, E) routed-token demand matrices plus token counts, consumed by
  ``SimulatorBackend.execute_trace`` and the runtime's re-planning loop
  (``ServerlessMoERuntime.run_trace``);
* **request traces** (lists of :class:`TraceRequest`) — timed prompt
  arrivals for the live serving engine (``ServingEngine.run(arrivals=…)``
  / ``ServingBackend.execute_requests``), so bursts exercise queueing
  and mid-stream slot admission for real.

Arrival processes: homogeneous Poisson, a two-state Markov-modulated
(bursty) Poisson, and a sinusoidally rate-modulated (diurnal) Poisson.
Demand processes: a Zipf popularity profile (the paper's skew), a
mixing-based popularity drift (each step blends toward a rotated
popularity, so hot experts cool and cold experts heat — the regime that
invalidates offline plans), and exact replay of a recorded
:class:`~repro.serving.telemetry.ExpertTelemetry`.

Everything is seeded; identical seeds give identical traces.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Iterator, List, Optional, Sequence

import numpy as np


@dataclass
class TraceRequest:
    """A timed request for the live serving engine."""

    arrival_step: int           # decode step at which the request arrives
    prompt: np.ndarray          # 1-D token ids
    max_new_tokens: int = 8
    tenant: Optional[str] = None  # owning tenant (fair-share admission)
    priority: int = 0           # higher admits first within fair-share


@dataclass
class TraceWindow:
    """One accounting window of a demand trace."""

    demand: np.ndarray          # (L, E) routed-token counts in the window
    num_tokens: int             # tokens served in the window
    t_start_s: float = 0.0      # window start on the trace clock

    def __post_init__(self):
        self.demand = np.asarray(self.demand, float)
        assert self.demand.ndim == 2, self.demand.shape
        self.num_tokens = int(self.num_tokens)


@dataclass
class Trace:
    """A sequence of demand windows (what a deployment lives through)."""

    windows: List[TraceWindow] = field(default_factory=list)

    def __len__(self) -> int:
        return len(self.windows)

    def __iter__(self) -> Iterator[TraceWindow]:
        return iter(self.windows)

    @property
    def num_tokens(self) -> int:
        return int(sum(w.num_tokens for w in self.windows))

    def total_demand(self) -> np.ndarray:
        """(L, E) sum over all windows."""
        assert self.windows, "empty trace"
        return np.sum([w.demand for w in self.windows], axis=0)


# ---------------------------------------------------------------------------
# Arrival processes (requests per step)
# ---------------------------------------------------------------------------

def poisson_arrivals(rate: float, steps: int, *, seed: int = 0) -> np.ndarray:
    """Homogeneous Poisson arrivals: (steps,) request counts per step."""
    assert rate >= 0 and steps >= 0
    rng = np.random.default_rng(seed)
    return rng.poisson(rate, size=steps).astype(np.int64)


def bursty_arrivals(rate: float, steps: int, *, burst_mult: float = 8.0,
                    p_enter: float = 0.1, p_exit: float = 0.4,
                    seed: int = 0) -> np.ndarray:
    """Two-state Markov-modulated Poisson process (quiet <-> burst).

    In the burst state the rate is ``burst_mult`` times the quiet rate;
    state transitions are Bernoulli per step (``p_enter``/``p_exit``).
    The multi-tenant traffic shape that defeats static provisioning.
    """
    assert burst_mult >= 1.0
    rng = np.random.default_rng(seed)
    out = np.zeros(steps, np.int64)
    bursting = False
    for t in range(steps):
        bursting = (rng.random() >= p_exit) if bursting \
            else (rng.random() < p_enter)
        out[t] = rng.poisson(rate * (burst_mult if bursting else 1.0))
    return out


def diurnal_arrivals(rate: float, steps: int, *, period: int = 48,
                     depth: float = 0.9, seed: int = 0) -> np.ndarray:
    """Sinusoidally rate-modulated Poisson (day/night load swing).

    ``depth`` in [0, 1] is the modulation depth: the instantaneous rate
    swings between ``rate * (1 - depth)`` and ``rate * (1 + depth)``
    over ``period`` steps.
    """
    assert 0.0 <= depth <= 1.0 and period > 0
    rng = np.random.default_rng(seed)
    t = np.arange(steps)
    lam = rate * (1.0 + depth * np.sin(2 * np.pi * t / period))
    return rng.poisson(np.maximum(lam, 0.0)).astype(np.int64)


# ---------------------------------------------------------------------------
# Expert-popularity processes
# ---------------------------------------------------------------------------

def zipf_popularity(num_layers: int, num_experts: int, *,
                    alpha: float = 1.2, seed: int = 0) -> np.ndarray:
    """(L, E) Zipf popularity fractions (rows sum to 1), independently
    permuted per layer — the paper's skewed expert-selection profile."""
    rng = np.random.default_rng(seed)
    zipf = (1.0 / np.arange(1, num_experts + 1)) ** alpha
    zipf = zipf / zipf.sum()
    return np.stack([rng.permutation(zipf) for _ in range(num_layers)])


def zipf_routing(n_tokens: int, num_experts: int, top_k: int, *,
                 alpha: float = 1.2, seed: int = 0) -> np.ndarray:
    """(n_tokens, top_k) expert assignments drawn (without replacement
    per token) from a Zipf(alpha) popularity — the skewed routing the
    dense capacity path drops under. Shared by the kernel benchmarks and
    the grouped-dispatch tests so skew fixtures cannot drift apart."""
    rng = np.random.default_rng(seed)
    p = (1.0 / np.arange(1, num_experts + 1)) ** alpha
    p /= p.sum()
    return np.stack([rng.choice(num_experts, size=top_k, replace=False,
                                p=p)
                     for _ in range(n_tokens)]).astype(np.int32)


def drift_popularity(popularity: np.ndarray, steps: int, *,
                     drift: float = 0.25,
                     seed: int = 0) -> Iterator[np.ndarray]:
    """Yield ``steps`` popularity matrices under gradual drift.

    Each step mixes the current popularity toward a per-layer random
    rotation of itself: ``p' = (1 - drift) * p + drift * rotate(p)``.
    Row sums are preserved, hot experts cool, previously cold experts
    heat up — exactly the non-stationarity that turns a once-optimal
    deployment into memory overruns (Alg. 2 case (i) feedback).
    """
    assert 0.0 <= drift <= 1.0
    rng = np.random.default_rng(seed)
    p = np.asarray(popularity, float).copy()
    L, E = p.shape
    for _ in range(steps):
        # E == 1: rotation is a no-op, popularity is trivially stationary
        target = np.stack([np.roll(p[e], int(rng.integers(1, E)) if E > 1
                           else 0) for e in range(L)])
        p = (1.0 - drift) * p + drift * target
        yield p.copy()


# ---------------------------------------------------------------------------
# Trace builders
# ---------------------------------------------------------------------------

def demand_trace(arrivals: np.ndarray, popularity, *,
                 tokens_per_request: int = 64,
                 window_s: float = 1.0) -> Trace:
    """Compose arrivals x popularity into a demand :class:`Trace`.

    ``popularity`` is either a fixed (L, E) matrix (rows summing to 1)
    or an iterable yielding one per window (e.g. ``drift_popularity``).
    Window ``t`` carries ``arrivals[t] * tokens_per_request`` tokens
    routed according to that window's popularity.
    """
    arrivals = np.asarray(arrivals, np.int64)
    if isinstance(popularity, np.ndarray):
        pops: Sequence[np.ndarray] = [popularity] * len(arrivals)
    else:
        pops = list(popularity)
        assert len(pops) >= len(arrivals), \
            f"popularity sequence ({len(pops)}) shorter than arrivals " \
            f"({len(arrivals)})"
    windows = []
    for t, n_req in enumerate(arrivals):
        tokens = int(n_req) * tokens_per_request
        windows.append(TraceWindow(demand=pops[t] * float(tokens),
                                   num_tokens=tokens,
                                   t_start_s=t * window_s))
    return Trace(windows=windows)


def replay_telemetry(telemetry, *, num_windows: int = 1,
                     window_s: float = 1.0) -> Trace:
    """Replay a recorded :class:`ExpertTelemetry` as a demand trace.

    The cumulative measured (L, E) demand and served token count are
    split evenly across ``num_windows`` windows (the trace's total is
    exactly the telemetry's total), so a live serving session can be
    re-executed against the simulator — with fault injection — under
    any candidate plan.
    """
    assert num_windows >= 1
    demand = telemetry.demand_matrix()
    total = int(telemetry.total_tokens)
    share = demand / num_windows
    base, rem = divmod(total, num_windows)
    return Trace(windows=[
        TraceWindow(demand=share, num_tokens=base + (1 if i < rem else 0),
                    t_start_s=i * window_s)
        for i in range(num_windows)])


def request_trace(arrivals: np.ndarray, vocab_size: int, *,
                  prompt_len: int = 8, max_new_tokens: int = 8,
                  steps_per_window: int = 4, seed: int = 0,
                  tenant: Optional[str] = None,
                  priority: int = 0) -> List[TraceRequest]:
    """Expand per-window arrival counts into timed engine requests.

    Window ``t`` contributes ``arrivals[t]`` requests arriving at decode
    step ``t * steps_per_window``, each with a random ``prompt_len``-token
    prompt — input for ``ServingEngine.run(arrivals=...)`` /
    ``ServingBackend.execute_requests``. ``tenant``/``priority`` stamp
    every request (interleave several calls for a multi-tenant arrival
    schedule).
    """
    rng = np.random.default_rng(seed)
    out: List[TraceRequest] = []
    for t, n_req in enumerate(np.asarray(arrivals, np.int64)):
        for _ in range(int(n_req)):
            out.append(TraceRequest(
                arrival_step=t * steps_per_window,
                prompt=rng.integers(0, vocab_size, size=prompt_len,
                                    dtype=np.int64),
                max_new_tokens=max_new_tokens,
                tenant=tenant, priority=priority))
    return out
