"""Render the §Dry-run and §Roofline tables of EXPERIMENTS.md from
experiments/dryrun/*.json. Injects between the AUTOGEN markers."""
from __future__ import annotations

import json
import re
import sys
from pathlib import Path

sys.path.insert(0, "src")
sys.path.insert(0, ".")

from benchmarks.roofline import analyze_record, render_table  # noqa: E402


def dryrun_table(recs, mesh):
    hdr = ("| arch | shape | status | compile s | args GB/dev | "
           "temp GB/dev | a2a MB | all-gather MB | all-reduce MB |\n"
           "|---|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in recs:
        if r["mesh"] != mesh or r.get("variant", "baseline") != "baseline":
            continue
        if r["status"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | skipped | - | - "
                         f"| - | - | - | - |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | ERROR | | | | | | |")
            continue
        m = r["memory"]
        c = r["collective_bytes_per_device"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | ok | {r['compile_s']:.1f} | "
            f"{m['argument_bytes'] / 1e9:.2f} | {m['temp_bytes'] / 1e9:.2f} | "
            f"{c['all-to-all'] / 1e6:.0f} | {c['all-gather'] / 1e6:.0f} | "
            f"{c['all-reduce'] / 1e6:.0f} |")
    return "\n".join(lines)


def main() -> None:
    recs = [json.loads(p.read_text())
            for p in sorted(Path("experiments/dryrun").glob("*.json"))]
    rows = [analyze_record(r) for r in recs]
    rows = [r for r in rows if r]

    blocks = {
        "DRYRUN_SINGLE": dryrun_table(recs, "single"),
        "DRYRUN_MULTI": dryrun_table(recs, "multi"),
        "ROOFLINE_SINGLE": render_table(rows, "single"),
        "ROOFLINE_MULTI": render_table(rows, "multi"),
    }
    path = Path("EXPERIMENTS.md")
    text = path.read_text()
    for key, table in blocks.items():
        pat = re.compile(
            rf"(<!-- AUTOGEN:{key} -->).*?(<!-- /AUTOGEN:{key} -->)",
            re.DOTALL)
        text = pat.sub(lambda m: f"{m.group(1)}\n{table}\n{m.group(2)}",
                       text)
    path.write_text(text)
    print("EXPERIMENTS.md tables refreshed")


if __name__ == "__main__":
    main()
