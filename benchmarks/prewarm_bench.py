"""Predictive pre-warming under bursty drift traffic: on vs off vs oracle.

Drives the discrete-event simulator's cold-start machinery over a bursty,
popularity-drifting demand trace three ways:

* **reactive** — the PR-3 baseline: only the ``FaultProfile`` warm pool
  absorbs cold starts;
* **predicted** — the :class:`~repro.predict.online.OnlinePredictor`
  (sliding-window decay) forecasts each window and pre-warms the plan's
  replicas for the experts it expects traffic on;
* **oracle** — perfect foresight, the lower envelope.

Rows report the cold-start count, billed cost, prewarm hits/misses, and
wasted keep-alive GB-seconds of each regime, plus the predictor's mean
per-window demand error. ``--smoke`` (CI) additionally ASSERTS the
acceptance contract: with prediction on, the cold-start count strictly
drops and so do the billed GB-seconds.

Pure numpy (no JAX model) so the suite runs in seconds.

Usage:
    PYTHONPATH=src:. python benchmarks/run.py --only prewarm_bench
    PYTHONPATH=src:. python benchmarks/prewarm_bench.py [--smoke]
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.core.costmodel import ModelProfile, PlatformSpec
from repro.core.simulator import FaultProfile, ServerlessSimulator
from repro.plan.backends import run_plan_over_trace
from repro.plan.planner import get_planner
from repro.predict import OnlinePredictor
from repro.traces import (bursty_arrivals, demand_trace, drift_popularity,
                          zipf_popularity)

SPEC = PlatformSpec()
PROF = ModelProfile(
    num_moe_layers=4, experts_per_layer=8,
    expert_param_bytes=28e6, token_in_bytes=3072.0, token_out_bytes=3072.0,
    u_ref_s=2e-4, intermediate_bytes=4e6, nonmoe_param_bytes=9e6)

FAULTS = FaultProfile(cold_start_prob=0.8, warm_pool=2)


def _trace(steps: int):
    pop = zipf_popularity(PROF.num_moe_layers, PROF.experts_per_layer,
                          seed=0)
    arr = np.maximum(bursty_arrivals(1.0, steps, burst_mult=8.0, seed=1), 1)
    arr[steps // 2] = max(int(arr.max()), 8)     # guarantee one real burst
    return demand_trace(arr, drift_popularity(pop, steps, drift=0.3,
                                              seed=2),
                        tokens_per_request=100)


def _run(plan, trace, *, predictor=None, prewarm=None):
    t0 = time.perf_counter()
    out = run_plan_over_trace(
        plan, trace,
        ServerlessSimulator(PROF, SPEC, seed=7, faults=FAULTS), PROF, SPEC,
        predictor=predictor, prewarm=prewarm)
    us = (time.perf_counter() - t0) * 1e6
    reps = out["reports"]
    return us, {
        "cold": sum(r.cold_starts for r in reps),
        "cost": sum(r.billed_cost for r in reps),
        "hits": sum(r.prewarm_hits for r in reps),
        "misses": sum(r.prewarm_misses for r in reps),
        "wasted_gb_s": sum(r.wasted_prewarm_gb_s for r in reps),
        "errors": out["prediction_errors"],
    }


def run(smoke: bool = False) -> None:
    steps = 8 if smoke else 24
    trace = _trace(steps)
    plan = get_planner("ods").plan(trace.windows[0].demand, PROF, SPEC,
                                   t_limit_s=1e9)

    us, reactive = _run(plan, trace)
    emit("prewarm_reactive", us,
         f"cold={reactive['cold']} cost=${reactive['cost']:.6f}")

    predictor = OnlinePredictor(PROF.num_moe_layers,
                                PROF.experts_per_layer, 16, decay=0.7)
    us, predicted = _run(plan, trace, predictor=predictor,
                         prewarm="predicted")
    mean_err = float(np.mean([e["mae"] for e in predicted["errors"]])) \
        if predicted["errors"] else float("nan")
    emit("prewarm_predicted", us,
         f"cold={predicted['cold']} cost=${predicted['cost']:.6f} "
         f"hits={predicted['hits']} misses={predicted['misses']} "
         f"wasted_gb_s={predicted['wasted_gb_s']:.3f} "
         f"mean_demand_mae={mean_err:.1f}")

    us, oracle = _run(plan, trace, prewarm="oracle")
    emit("prewarm_oracle", us,
         f"cold={oracle['cold']} cost=${oracle['cost']:.6f} "
         f"hits={oracle['hits']} misses={oracle['misses']}")

    if smoke:
        # acceptance contract: prediction strictly beats reactive, and
        # perfect foresight bounds it from below
        assert predicted["cold"] < reactive["cold"], \
            (predicted["cold"], reactive["cold"])
        assert predicted["cost"] < reactive["cost"], \
            (predicted["cost"], reactive["cost"])
        assert oracle["cold"] <= predicted["cold"]
        assert oracle["misses"] == 0
        print("prewarm_smoke,0.0,ok")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="reduced scales for CI + acceptance asserts")
    print("name,us_per_call,derived")
    run(smoke=ap.parse_args().smoke)
