"""Fig. 10: expert-selection prediction accuracy.

Average absolute difference per expert between real and predicted routed-
token counts, across models / expert counts / top-k, ours (token+position+
attention-ID posterior, Eq. 1-2) vs the Lina baseline (token-ID only).
The corpus is the synthetic Zipf stand-in (EXPERIMENTS.md §Setup).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, small_runtime
from repro.predict import ExpertPredictor, predict_demand_reference

CASES = [
    ("bert-moe", {}),                       # basic Bert MoE: 4e top-1
    ("bert-moe", {"variant_experts": 8}),
    ("bert-moe", {"variant_experts": 16}),
    ("bert-moe", {"variant_top_k": 2}),     # top-2 routing
    ("gpt2-moe", {}),                       # basic GPT2 MoE
    ("gpt2-moe", {"seed": 7}),              # different corpus (cf. Lambda)
    ("bert2bert-moe", {}),                  # basic Bert2Bert MoE
]


def _demand_hot_path_speedup() -> None:
    """Satellite row: the vectorized ``predict_demand`` (one dense-tensor
    argsort/einsum pass) vs the historical per-layer, per-unique-token
    loop — verified exactly equal on the same table before timing."""
    rt = small_runtime("gpt2-moe")
    rt.profile_table()
    b = rt.learn_batches()[0]
    p = ExpertPredictor(rt.table, top_k=rt.top_k).fit()
    import numpy as np
    np.testing.assert_array_equal(p.predict_demand(b, mode="map"),
                                  predict_demand_reference(p, b,
                                                           mode="map"))
    reps = 5
    t0 = time.perf_counter()
    for _ in range(reps):
        predict_demand_reference(p, b, mode="map")
    t_loop = (time.perf_counter() - t0) / reps
    t0 = time.perf_counter()
    for _ in range(reps):
        p.predict_demand(b, mode="map")
    t_vec = (time.perf_counter() - t0) / reps
    emit("fig10_demand_vectorized", t_vec * 1e6,
         f"speedup={t_loop / max(t_vec, 1e-9):.1f}x "
         f"loop_us={t_loop * 1e6:.0f}")


def run() -> None:
    _demand_hot_path_speedup()
    for arch, over in CASES:
        tag = arch + "".join(f"_{k}{v}" for k, v in over.items())
        rt = small_runtime(arch, **over)
        rt.profile_table()
        b = rt.learn_batches()[0]
        real = rt.real_demand(b)
        for mode in ("full", "lina"):
            t0 = time.perf_counter()
            p = ExpertPredictor(rt.table, mode=mode, top_k=rt.top_k).fit()
            dem = p.predict_demand(b, mode="map")       # Eq. 2 (paper)
            us = (time.perf_counter() - t0) * 1e6
            diff = p.prediction_difference(dem, real)
            name = "ours" if mode == "full" else "lina"
            emit(f"fig10_{tag}_{name}", us, f"diff={diff:.2f}")
        # beyond-paper: expected-count demand (ablation)
        p = ExpertPredictor(rt.table, top_k=rt.top_k).fit()
        dem = p.predict_demand(b, mode="expected")
        emit(f"fig10_{tag}_ours_expected", 0.0,
             f"diff={p.prediction_difference(dem, real):.2f}")


if __name__ == "__main__":
    run()
