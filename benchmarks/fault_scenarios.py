"""Fault-scenario suite: how deployments survive a misbehaving platform.

The paper's evaluation assumes well-behaved Lambda invocations; this
suite drives the discrete-event simulator's :class:`FaultProfile` knobs
(cold-start storms, straggler tails, transient failures with retry,
per-account concurrency caps) and trace-driven traffic (bursty arrivals,
expert-popularity drift) against ODS plans, reporting:

* cost/latency inflation of each fault regime vs. the ideal platform
  (`fault_<scenario>` rows);
* what re-planning from failure feedback buys under drift + bursts:
  a static stale plan vs. the Alg.-2 feedback loop re-planning per
  window (`fault_replan_*` rows), including how far the re-planned
  replication/memory moved from the fault-free plan.

Pure numpy (no JAX model) so the suite runs in seconds.

Usage:
    PYTHONPATH=src:. python benchmarks/run.py --only fault_scenarios
    PYTHONPATH=src:. python benchmarks/fault_scenarios.py [--smoke]
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.core.costmodel import ModelProfile, PlatformSpec
from repro.core.simulator import FaultProfile, ServerlessSimulator
from repro.plan.backends import run_plan_over_trace
from repro.plan.planner import get_planner
from repro.plan.schema import plan_diff
from repro.traces import (bursty_arrivals, demand_trace, drift_popularity,
                          zipf_popularity)

SPEC = PlatformSpec()
PROF = ModelProfile(
    num_moe_layers=4, experts_per_layer=8,
    expert_param_bytes=28e6, token_in_bytes=3072.0, token_out_bytes=3072.0,
    u_ref_s=2e-4, intermediate_bytes=4e6, nonmoe_param_bytes=9e6)

SCENARIOS = {
    "cold_start_storm": FaultProfile(cold_start_prob=0.8, warm_pool=4),
    "straggler_tail": FaultProfile(straggler_prob=0.15,
                                   straggler_slowdown=6.0),
    "transient_failures": FaultProfile(failure_prob=0.25, max_retries=3,
                                       retry_backoff_s=0.1),
    "concurrency_capped": FaultProfile(concurrency_limit=4),
    "the_works": FaultProfile(cold_start_prob=0.5, warm_pool=2,
                              straggler_prob=0.1, straggler_slowdown=4.0,
                              failure_prob=0.1, concurrency_limit=8),
}


def _demand(L=4, E=8, seed=0, scale=2000):
    rng = np.random.default_rng(seed)
    zipf = (1.0 / np.arange(1, E + 1)) ** 1.2
    d = scale * zipf / zipf.sum() * E
    return np.stack([rng.permutation(d) for _ in range(L)])


def _fault_regimes(smoke: bool) -> None:
    d = _demand(scale=600 if smoke else 2000)
    plan = get_planner("ods").plan(d, PROF, SPEC, t_limit_s=1e9)
    n_tok = int(d.sum())
    base = ServerlessSimulator(PROF, SPEC, seed=7).run(plan, d, n_tok)
    for name, faults in SCENARIOS.items():
        t0 = time.perf_counter()
        rep = ServerlessSimulator(PROF, SPEC, seed=7,
                                  faults=faults).run(plan, d, n_tok)
        emit(f"fault_{name}", (time.perf_counter() - t0) * 1e6,
             f"cost_x={rep.billed_cost / base.billed_cost:.3f} "
             f"lat_x={rep.latency_s / base.latency_s:.3f} "
             f"cold={rep.cold_starts} retries={rep.retries} "
             f"straggled={rep.stragglers} "
             f"queue_s={rep.queue_delay_s:.2f}")


def _drift_replan(smoke: bool) -> None:
    """Bursty + drifting traffic: static stale plan vs. feedback re-plan.

    Runs in the paper's binding-payload regime (the cap scaled to the
    bench's token scale, as in ``common.paper_regime_spec``) so bursts
    push direct-transfer replicas past the payload cap — Alg. 2 case
    (ii) — and drift makes the stale plan's sizing wrong.
    """
    steps = 6 if smoke else 16
    scale = 200          # quiet-window hot-expert load sits under the cap
    spec = PlatformSpec(payload_mb=0.4)
    pop = zipf_popularity(PROF.num_moe_layers, PROF.experts_per_layer,
                          seed=0)
    arr = bursty_arrivals(1.0, steps, burst_mult=8.0, seed=1)
    arr = np.maximum(arr, 1)                     # no dead windows
    arr[steps // 2] = max(int(arr.max()), 8)     # guarantee one real burst
    trace = demand_trace(arr, drift_popularity(pop, steps, drift=0.35,
                                               seed=2),
                         tokens_per_request=scale)
    faults = SCENARIOS["the_works"]
    plan0 = get_planner("ods").plan(trace.windows[0].demand, PROF, spec,
                                    t_limit_s=1e9)

    def run(replan: bool):
        out = run_plan_over_trace(
            plan0, trace,
            ServerlessSimulator(PROF, spec, seed=7, faults=faults),
            PROF, spec,
            plan_fn=(lambda d: get_planner("ods").plan(d, PROF, spec,
                                                       t_limit_s=1e9))
            if replan else None)
        cost = sum(r.billed_cost for r in out["reports"])
        overruns = sum(int(r.mem_overrun.sum()) for r in out["reports"])
        return cost, overruns, out["replans"], out["final_plan"]

    t0 = time.perf_counter()
    static_cost, static_over, _, _ = run(replan=False)
    replan_cost, replan_over, n_replans, final = run(replan=True)
    diff = plan_diff(plan0, final)
    emit("fault_replan_drift", (time.perf_counter() - t0) * 1e6,
         f"static_cost=${static_cost:.4f} replan_cost=${replan_cost:.4f} "
         f"overruns {static_over}->{replan_over} replans={n_replans} "
         f"replicas+={diff['replicas_added']} "
         f"mem_delta_mb={diff['mem_mb_delta_total']:.0f}")


def run(smoke: bool = False) -> None:
    _fault_regimes(smoke)
    _drift_replan(smoke)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="reduced scales for CI")
    print("name,us_per_call,derived")
    run(smoke=ap.parse_args().smoke)
