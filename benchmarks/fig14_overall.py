"""Fig. 14: overall billed cost + throughput across deployment baselines.

Serverless (BO / real-distribution oracle / no-BO / Lina / LambdaML /
random) vs CPU cluster (plain + betterTransformer) for Bert-MoE and
GPT2-MoE. The paper's headline claims: >=75.67% cheaper than the CPU
cluster and >=43.41% cheaper than LambdaML with <=18.76% throughput loss.
"""
from __future__ import annotations

import time

from benchmarks.common import emit, paper_regime_spec, small_runtime


def run(bo_iters: int = 4) -> None:
    for arch in ("bert-moe", "gpt2-moe"):
        # MAP demand (Eq. 2, paper-faithful) + a serving SLO tight enough
        # that ODS must buy memory/replicas for throughput (paper's setup)
        rt = small_runtime(arch, demand_mode="map", slo_s=8.0,
                           spec=paper_regime_spec())
        res = rt.run_bo(Q=40, max_iters=bo_iters, seed=0)
        t0 = time.perf_counter()
        out = rt.evaluate_all(bo_table=res.best_table)
        us = (time.perf_counter() - t0) * 1e6 / max(len(out), 1)
        ours = out["serverless_bo"]["billed_cost"]
        for name, v in out.items():
            emit(f"fig14_{arch}_{name}", us,
                 f"cost=${v['billed_cost']:.6f};"
                 f"tput={v['throughput_tps']:.1f}t/s")
        cpu = out["cpu_cluster"]["billed_cost"]
        lam = out["lambdaml"]["billed_cost"]
        emit(f"fig14_{arch}_headline", 0.0,
             f"vs_cpu={100 * (1 - ours / cpu):.1f}%_cheaper;"
             f"vs_lambdaml={100 * (1 - ours / lam):.1f}%_cheaper;"
             f"tput_drop_vs_lambdaml="
             f"{100 * (1 - out['serverless_bo']['throughput_tps'] / out['lambdaml']['throughput_tps']):.1f}%")


if __name__ == "__main__":
    run()
