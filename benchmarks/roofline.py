"""Roofline analysis over the dry-run artifacts (deliverable g).

Reads ``experiments/dryrun/*.json`` (written by ``repro.launch.dryrun``)
and derives the three roofline terms per (arch x shape x mesh):

    compute    = FLOPs_per_device / peak_FLOPs          [s]
    memory     = bytes_per_device / HBM_bw              [s]
    collective = wire_bytes_per_device / ICI_link_bw    [s]

cost_analysis reports PER-DEVICE quantities for the SPMD-partitioned
module, so no device multiplication is needed for the time terms.
Wire bytes apply a per-op factor on the HLO result sizes: all-reduce moves
~2x its payload on a ring, all-gather/reduce-scatter/all-to-all ~1x
(× (n-1)/n ≈ 1), collective-permute 1x.

MODEL_FLOPS uses 6·N_active·D for training (fwd+bwd), 2·N_active·D for
prefill, 2·N_active·B for one decode step; the ratio to compiled HLO FLOPs
exposes remat recompute and masked-flash overcounting.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Optional

# TPU v5e hardware constants (per chip) live with the kernel autotuner,
# which scores block-size candidates against the same roofline terms —
# one source of truth for both analyses.
from repro.kernels.autotune import HBM_BW, ICI_BW, PEAK_FLOPS

WIRE_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
               "all-to-all": 1.0, "collective-permute": 1.0}


def analytic_costs(arch: str, shape_name: str, num_devices: int) -> Dict:
    """Napkin-math per-device FLOPs / HBM bytes / wire bytes.

    Needed because XLA's cost analysis counts a rolled While body ONCE
    (verified experimentally), so the compiled numbers undercount the
    block-scan by ~num_blocks. Formulas:

    FLOPs: dense-matmul model. fwd = 2*N_active*T + attention scores
    2*2*B*S*T_att*nh*hd per layer (x2: rectangular flash schedule).
    train = 3x fwd (bwd) + 1x fwd (remat recompute) = 4x. decode T=1 new
    token per sequence but scores read the whole cache.

    HBM bytes: params touched once per step (train: bf16 params+grads +
    f32 mu/nu read+write = 22 B/param) + activation traffic
    ~12 B/token/feature/layer (+50% remat re-reads, train) + KV cache
    read for decode.

    Wire bytes: TP all-reduces 2 activations/layer (2x wire factor) +
    MoE all_to_all 2x dispatch buffers + (train) DP gradient
    reduce-scatter/all-gather 4 B/param across the data axis.
    """
    from repro.config import SHAPES, get_arch
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    B, S = shape.global_batch, shape.seq_len
    d, L = cfg.d_model, cfg.num_layers
    nh, hd = cfg.num_heads, cfg.resolved_head_dim
    n_act = cfg.active_param_count()
    n_tot = cfg.param_count()
    model_axis = 16
    data_ways = num_devices // model_axis

    # attention context length per layer kind
    att_flops = 0.0
    new_tok = B * (S if shape.kind != "decode" else 1)
    for spec in cfg.pattern:
        T_att = (min(cfg.sliding_window, S)
                 if spec.mixer == "swa" else S)
        if spec.mixer in ("attn", "swa", "shared_attn"):
            att_flops += (2 * 2 * new_tok * T_att * nh * hd
                          * cfg.num_blocks * 2)     # x2 rectangular flash
    att_flops /= len(cfg.pattern)

    fwd = 2.0 * n_act * new_tok + att_flops
    if shape.kind == "train":
        flops = 4.0 * fwd                            # bwd + remat recompute
    else:
        flops = fwd

    # HBM bytes
    act = 12.0 * new_tok * d * L
    if shape.kind == "train":
        byts = 22.0 * n_tot + 1.5 * act
    elif shape.kind == "prefill":
        byts = 2.0 * n_tot + act
    else:
        kv_per_tok = sum(
            2 * 2 * cfg.num_kv_heads * hd
            * (min(cfg.sliding_window, S) if sp.mixer == "swa" else S) / S
            for sp in cfg.pattern) / len(cfg.pattern) * L
        byts = 2.0 * n_act + act + B * S * kv_per_tok

    # wire bytes (model-axis collectives + train-time grad sync)
    wire = 2.0 * 2 * (2.0 * new_tok * d) * L         # 2 all-reduce/layer
    if cfg.has_moe and cfg.moe is not None:
        wire += 2.0 * 2 * new_tok * cfg.moe.top_k * d   # all_to_all x2
    if shape.kind == "train":
        wire += 4.0 * n_tot / data_ways * 2          # grad all-reduce

    return {"flops": flops / num_devices,
            "bytes": byts / num_devices,
            "wire": wire / num_devices}


def model_flops(arch: str, shape_name: str) -> float:
    from repro.config import SHAPES, get_arch
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    n = cfg.active_param_count()
    if shape.kind == "train":
        toks = shape.global_batch * shape.seq_len
        return 6.0 * n * toks
    if shape.kind == "prefill":
        toks = shape.global_batch * shape.seq_len
        return 2.0 * n * toks
    return 2.0 * n * shape.global_batch          # one decode step


def analyze_record(rec: Dict) -> Optional[Dict]:
    if rec.get("status") != "ok":
        return None
    nd = rec["num_devices"]
    # raw compiled terms (XLA counts rolled While bodies once -> these
    # undercount the block scan; kept as the compiled-artifact cross-check)
    comp_h = rec["flops_per_device"] / PEAK_FLOPS
    mem_h = rec["bytes_per_device"] / HBM_BW
    wire_h = sum(WIRE_FACTOR[k] * v
                 for k, v in rec["collective_bytes_per_device"].items())
    coll_h = wire_h / ICI_BW
    # analytic terms (primary for dominance; see analytic_costs docstring)
    an = analytic_costs(rec["arch"], rec["shape"], nd)
    comp = an["flops"] / PEAK_FLOPS
    mem = an["bytes"] / HBM_BW
    coll = max(an["wire"] / ICI_BW, coll_h)
    terms = {"compute": comp, "memory": mem, "collective": coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"])
    hlo_total = rec["flops_per_device"] * nd
    an_total = an["flops"] * nd
    ratio = mf / an_total if an_total else float("nan")
    mem_gb = (rec["memory"]["argument_bytes"] + rec["memory"]["temp_bytes"]
              + rec["memory"]["output_bytes"]
              - rec["memory"]["alias_bytes"]) / 1e9
    return {
        **{k: rec[k] for k in ("arch", "shape", "mesh")},
        "variant": rec.get("variant", "baseline"),
        "num_devices": nd,
        "compute_s": comp,
        "memory_s": mem,
        "collective_s": coll,
        "hlo_compute_s": comp_h,
        "hlo_memory_s": mem_h,
        "hlo_collective_s": coll_h,
        "dominant": dominant,
        "model_flops": mf,
        "hlo_flops_total": hlo_total,
        "useful_ratio": ratio,
        "device_mem_gb": mem_gb,
        "suggestion": _suggest(rec, dominant, ratio),
    }


def _suggest(rec: Dict, dominant: str, ratio: float) -> str:
    arch, shape = rec["arch"], rec["shape"]
    if dominant == "collective":
        return ("overlap/shrink collectives: chunked all_to_all (beta "
                "pipelining) or move the dominant matmul's sharding axis")
    if dominant == "memory":
        if "decode" in shape or shape == "long_500k":
            return ("decode is cache-bandwidth-bound: shrink KV (GQA/"
                    "window/quantized cache) or raise batch to amortize "
                    "weight reads")
        return ("cut activation traffic: larger fusion blocks, bf16 "
                "residuals, fewer remat round-trips")
    if ratio < 0.4:
        return ("compute-bound but low useful ratio: reduce remat "
                "recompute and masked-flash overcounting before scaling")
    return "compute-bound near roofline: scale batch or add chips"


def load_all(dryrun_dir: str = "experiments/dryrun") -> List[Dict]:
    out = []
    for p in sorted(Path(dryrun_dir).glob("*.json")):
        rec = json.loads(p.read_text())
        row = analyze_record(rec)
        if row:
            out.append(row)
    return out


def render_table(rows: List[Dict], mesh: str = "single") -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | "
           "dominant | useful ratio | mem GB/dev |\n"
           "|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in rows:
        if r["mesh"] != mesh or r.get("variant", "baseline") != "baseline":
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.2e} | "
            f"{r['memory_s']:.2e} | {r['collective_s']:.2e} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
            f"{r['device_mem_gb']:.1f} |")
    return "\n".join(lines)


def main() -> None:
    rows = load_all()
    print(render_table(rows, "single"))
    print()
    print("multi-pod (512 chips):")
    print(render_table(rows, "multi"))
    # CSV summary for benchmarks/run.py
    for r in rows:
        if r["mesh"] == "single":
            dom_s = r[f"{r['dominant']}_s"]
            print(f"roofline_{r['arch']}_{r['shape']},"
                  f"{dom_s * 1e6:.1f},{r['dominant']}")


if __name__ == "__main__":
    main()
