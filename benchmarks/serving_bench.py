"""Serving throughput + TTFT: continuous batching vs. the old drain loop.

The seed engine drained the queue in FIXED batches: pick ``batch_size``
requests, prefill them together (left-padded to the longest prompt), decode
until ALL of them finish, only then touch the queue again. A short request
therefore holds its lane idle while the longest one in its batch drags on,
and requests behind the batch wait the full batch duration for a first
token. The rebuilt ``repro.serving`` engine admits queued requests into
slots the moment they free up.

This bench replays the SAME ragged workload (mixed prompt lengths, mixed
generation lengths) through both schedulers and reports tokens/s and mean
time-to-first-token. Emits CSV rows per the harness contract:

    serving.<engine>.tokens_per_s,us_total,tok_per_s
    serving.<engine>.ttft_ms,us_total,mean_ttft_ms

``kernels_comparison`` additionally replays one workload through the
engine's kernel paths: ``kernels="reference"`` (the PR-4 hot path, full
``max_len`` attention reads every decode step) vs ``kernels="fused"``
(fused single-pass routing + bucketed ragged ``kv_len`` decode). Outputs
must match token-for-token; ``--smoke`` (CI) additionally enforces a
steady-state tokens/s FLOOR on the fused path and writes
``BENCH_serving.json``.

Run:  PYTHONPATH=src:. python benchmarks/serving_bench.py [--smoke]
"""
from __future__ import annotations

import argparse
import json
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.config import get_arch, reduced_config
from repro.models import Model
from repro.serving import ServingEngine


# --------------------------------------------------------------------------
# The seed engine's fixed-batch drain loop, kept verbatim as the baseline.
# --------------------------------------------------------------------------

class DrainLoopBaseline:
    """Fixed-batch drain scheduling (the pre-rebuild ServingEngine.run)."""

    def __init__(self, model: Model, params, *, max_len: int,
                 batch_size: int):
        self.model = model
        self.params = params
        self.max_len = max_len
        self.batch_size = batch_size
        self._decode = jax.jit(model.decode_step)
        self._prefill = jax.jit(
            lambda p, t: model.prefill(p, t))

    def serve(self, prompts: List[np.ndarray], max_new: List[int]):
        """Returns (total_new_tokens, ttft_s per request)."""
        t_start = time.perf_counter()
        ttft: List[float] = []
        total = 0
        queue = list(zip(prompts, max_new))
        while queue:
            batch = queue[:self.batch_size]
            queue = queue[self.batch_size:]
            S = max(len(p) for p, _ in batch)
            toks = np.zeros((len(batch), S), np.int32)
            for i, (p, _) in enumerate(batch):
                toks[i, S - len(p):] = p              # left-pad
            logits, cache = self._prefill(self.params, jnp.asarray(toks))
            cache = self.model.prepare_decode_cache(cache, self.max_len)
            next_tok = np.asarray(jnp.argmax(logits[:, -1], -1))
            now = time.perf_counter() - t_start
            ttft.extend([now] * len(batch))
            emitted = [1] * len(batch)
            total += len(batch)
            steps = max(m for _, m in batch) - 1
            for step in range(steps):
                logits, cache = self._decode(
                    self.params, jnp.asarray(next_tok[:, None]), cache,
                    jnp.int32(S + step))
                next_tok = np.asarray(jnp.argmax(logits[:, -1], -1))
                for i, (_, m) in enumerate(batch):
                    if emitted[i] < m:
                        emitted[i] += 1
                        total += 1
        return total, ttft


def make_workload(cfg, n_requests: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab_size,
                            size=int(rng.integers(4, 24))).astype(np.int32)
               for _ in range(n_requests)]
    max_new = [int(rng.integers(2, 24)) for _ in range(n_requests)]
    return prompts, max_new


def run(arch: str = "gpt2-moe", n_requests: int = 12, slots: int = 4,
        max_len: int = 64) -> None:
    cfg = reduced_config(get_arch(arch))
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    prompts, max_new = make_workload(cfg, n_requests)

    # Warm each scheduler ON ITS MEASURED INSTANCE: jit caches live per
    # engine object, so steady-state serving (the number that matters for a
    # long-lived server) is measured after one full warm pass through the
    # same workload shapes.
    drain = DrainLoopBaseline(model, params, max_len=max_len,
                              batch_size=slots)
    drain.serve(prompts, max_new)
    eng = ServingEngine(model, params, max_len=max_len,
                        batch_size=slots, collect_telemetry=False)
    for p, m in zip(prompts, max_new):
        eng.submit(p, max_new_tokens=m)
    eng.run(max_steps=10_000)

    # --- old: fixed-batch drain loop -------------------------------------
    t0 = time.perf_counter()
    n_old, ttft_old = drain.serve(prompts, max_new)
    dt_old = time.perf_counter() - t0
    emit("serving.drain.tokens_per_s", dt_old * 1e6, f"{n_old / dt_old:.2f}")
    emit("serving.drain.ttft_ms", dt_old * 1e6,
         f"{1e3 * float(np.mean(ttft_old)):.1f}")

    # --- new: continuous batching ----------------------------------------
    t0 = time.perf_counter()
    reqs = [eng.submit(p, max_new_tokens=m)
            for p, m in zip(prompts, max_new)]
    done = eng.run(max_steps=10_000)
    dt_new = time.perf_counter() - t0
    n_new = sum(len(r.output) for r in done)
    ttft_new = [r.ttft_s for r in reqs if r.ttft_s is not None]
    emit("serving.continuous.tokens_per_s", dt_new * 1e6,
         f"{n_new / dt_new:.2f}")
    emit("serving.continuous.ttft_ms", dt_new * 1e6,
         f"{1e3 * float(np.mean(ttft_new)):.1f}")
    emit("serving.speedup", 0.0,
         f"{(n_new / dt_new) / (n_old / dt_old):.2f}x")


def kernels_comparison(arch: str = "gpt2-moe", n_requests: int = 8,
                       slots: int = 4, max_len: int = 256,
                       max_new: int = 24, floor: float = 0.0) -> dict:
    """Fused kernel path vs the reference engine on one ragged workload.

    ``max_len`` is deliberately generous relative to the served lengths:
    the reference path attends over all ``max_len`` cache rows every
    decode step, while the fused path's bucketed ``kv_len`` reads only
    the occupied prefix — that gap IS the optimisation being measured.
    Outputs must match token-for-token (the fused path is equivalence-
    pinned, not approximate). With ``floor > 0`` the fused tokens/s must
    reach ``floor *`` the reference tokens/s; the CI floor is set well
    under 1.0 so it catches the fused path falling off a cliff, not
    scheduler jitter on shared runners.
    """
    cfg = reduced_config(get_arch(arch))
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size,
                            size=int(rng.integers(4, 16))).astype(np.int32)
               for _ in range(n_requests)]

    rates, outs = {}, {}
    for kern in ("reference", "fused"):
        eng = ServingEngine(model, params, max_len=max_len,
                            batch_size=slots, collect_telemetry=False,
                            kernels=kern)
        # warm pass: steady-state rates, measured after jit caches (and
        # the fused path's kv_len buckets) exist for these shapes
        for p in prompts:
            eng.submit(p, max_new_tokens=max_new)
        eng.run(max_steps=10_000)
        t0 = time.perf_counter()
        reqs = [eng.submit(p, max_new_tokens=max_new) for p in prompts]
        done = eng.run(max_steps=10_000)
        dt = time.perf_counter() - t0
        n_tok = sum(len(r.output) for r in done)
        rates[kern] = n_tok / dt
        outs[kern] = [r.output for r in reqs]
        emit(f"serving.kernels.{kern}.tokens_per_s", dt * 1e6,
             f"{rates[kern]:.2f}")

    assert outs["fused"] == outs["reference"], \
        "fused kernel path drifted from the reference engine's outputs"
    ratio = rates["fused"] / rates["reference"]
    emit("serving.kernels.fused_speedup", 0.0, f"{ratio:.2f}x")
    if floor > 0.0:
        assert ratio >= floor, (
            f"fused path fell past the throughput floor: "
            f"{rates['fused']:.1f} tok/s vs reference "
            f"{rates['reference']:.1f} tok/s (floor {floor}x)")
    return {"tokens_per_s": rates, "fused_speedup": ratio,
            "outputs_match": True, "arch": arch, "max_len": max_len}


def smoke(out_path: str = "BENCH_serving.json") -> None:
    results = kernels_comparison(n_requests=6, slots=3, max_len=192,
                                 max_new=16, floor=0.8)
    with open(out_path, "w") as f:
        json.dump(results, f, indent=1, sort_keys=True)
    print(f"wrote {out_path}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt2-moe")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--smoke", action="store_true",
                    help="CI: fused-vs-reference floor + BENCH_serving.json")
    args = ap.parse_args()
    if args.smoke:
        smoke()
    else:
        run(args.arch, args.requests, args.slots, args.max_len)
        kernels_comparison()


if __name__ == "__main__":
    main()
