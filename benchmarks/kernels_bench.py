"""Kernel micro-benchmarks: Pallas (interpret) vs pure-jnp reference.

NOTE: interpret-mode wall times on CPU measure the Python emulation, not
TPU performance — the derived field therefore reports the kernel's
ANALYTIC TPU utilisation instead: FLOPs / (wall_at_peak) assuming the
documented BlockSpec tiling, plus the allclose check against the oracle.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.kernels.decode_attention.ops import decode_attention_pallas
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.expert_ffn.ops import expert_ffn_pallas
from repro.kernels.expert_ffn.ref import expert_ffn_ref
from repro.kernels.router_topk.ops import router_topk_pallas
from repro.kernels.router_topk.ref import router_topk_ref

PEAK = 197e12


def _time(fn, *args, reps=3):
    fn(*args)
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def run() -> None:
    ks = jax.random.split(jax.random.PRNGKey(0), 4)

    # expert FFN: qwen2-moe-like local tile (4 experts x 512 cap x 2048)
    E, C, D, F = 4, 512, 256, 352
    buf = 0.3 * jax.random.normal(ks[0], (E, C, D))
    wg = 0.2 * jax.random.normal(ks[1], (E, D, F))
    wu = 0.2 * jax.random.normal(ks[2], (E, D, F))
    wd = 0.2 * jax.random.normal(ks[3], (E, F, D))
    us = _time(lambda *a: expert_ffn_pallas(*a), buf, wg, wu, wd)
    ref = expert_ffn_ref(buf, wg, wu, wd)
    got = expert_ffn_pallas(buf, wg, wu, wd)
    err = float(jnp.abs(got - ref).max())
    flops = 2 * 3 * E * C * D * F
    emit("kernel_expert_ffn", us,
         f"allclose_err={err:.1e};tpu_us_at_peak={flops / PEAK * 1e6:.2f}")

    # router top-k: 60-expert qwen2-moe router
    N, Dr, Er, k = 2048, 256, 60, 4
    x = jax.random.normal(ks[0], (N, Dr))
    w = jax.random.normal(ks[1], (Dr, Er))
    us = _time(lambda *a: router_topk_pallas(*a, k=k), x, w)
    vals, idx = router_topk_pallas(x, w, k=k)
    rv, ri = router_topk_ref(x, w, k)
    emit("kernel_router_topk", us,
         f"idx_match={bool((idx == ri).all())};"
         f"tpu_us_at_peak={2 * N * Dr * Er / PEAK * 1e6:.2f}")

    # decode attention: 32k cache tile
    B, Nh, G, Dh, T = 1, 2, 4, 128, 8192
    q = jax.random.normal(ks[0], (B, Nh, G, Dh))
    kc = jax.random.normal(ks[1], (B, T, Nh, Dh))
    vc = jax.random.normal(ks[2], (B, T, Nh, Dh))
    us = _time(lambda *a: decode_attention_pallas(*a, T - 5), q, kc, vc)
    got = decode_attention_pallas(q, kc, vc, T - 5)
    ref = decode_attention_ref(q, kc, vc, T - 5)
    err = float(jnp.abs(got - ref).max())
    hbm_bytes = 2 * B * T * Nh * Dh * 4
    emit("kernel_decode_attention", us,
         f"allclose_err={err:.1e};"
         f"tpu_us_at_hbm_bw={hbm_bytes / 819e9 * 1e6:.2f}")


if __name__ == "__main__":
    run()
