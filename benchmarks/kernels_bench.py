"""Kernel micro-benchmarks: Pallas (interpret) vs pure-jnp reference.

NOTE: interpret-mode wall times on CPU measure the Python emulation, not
TPU performance — the derived field therefore reports the kernel's
ANALYTIC TPU utilisation instead: FLOPs / (wall_at_peak) assuming the
documented BlockSpec tiling, plus the allclose check against the oracle.

``moe_dispatch_sweep`` compares the DENSE capacity-buffer MoE execution
path against the DROPLESS grouped ragged-GEMM path over Zipf routing
skew: dense FLOPs stay pinned to ``E * capacity`` whatever the skew
(padding cold experts with dead rows while dropping the hot experts'
overflow), grouped FLOPs track the tokens actually routed.
``fused_routing_bench`` times the single-pass fused routing front-end
(one top_k + one-hot cumsum) against the separate-pass baseline
(top_k, then argsort/bincount/cumsum inside the plan builder) and
enforces a routed-pairs/s FLOOR on the fused path. ``--smoke`` runs one
reduced sweep point + the parity checks + the fused-routing floor (CI).
Every emitted row also lands machine-readable in ``BENCH_kernels.json``.
"""
from __future__ import annotations

import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.kernels.decode_attention.ops import decode_attention_pallas
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.expert_ffn.ops import expert_ffn_pallas
from repro.kernels.expert_ffn.ref import expert_ffn_ref
from repro.kernels.grouped_moe.ops import grouped_moe_pallas
from repro.kernels.grouped_moe.ref import grouped_moe_ref
from repro.kernels.router_topk.ops import router_topk_pallas
from repro.kernels.router_topk.ref import router_topk_ref

PEAK = 197e12


def _time(fn, *args, reps=3):
    fn(*args)
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def moe_dispatch_sweep(smoke: bool = False) -> None:
    """Dense capacity buffers vs dropless grouped GEMM across Zipf skew.

    Emits, per skew level: the row counts each path COMPUTES
    (dense_rows = E * capacity, constant; grouped_rows tracks the routed
    pairs up to block padding), the pairs dense DROPS, the analytic TPU
    microseconds of each, and the measured jnp wall time. The grouped
    layout is materialized at its realized size (host-known routing) so
    the measured time scales with actual load, exactly as the Pallas
    kernel's grid would on hardware.
    """
    from repro.config import MoEConfig
    from repro.models.moe import (build_dispatch, build_grouped_dispatch,
                                  capacity_for, dispatch_grouped,
                                  dispatch_tokens, expert_ffn,
                                  grouped_expert_ffn)
    from repro.traces import zipf_routing

    E, D, F, k, bn = 8, 64, 96, 2, 8
    N = 128 if smoke else 512
    # cf=2.0 (a typical low-drop setting): dense provisions 2x the mean
    # load PER EXPERT and still drops once skew concentrates more than
    # 2x on a hot expert — paying double FLOPs AND losing tokens, while
    # grouped pays exactly the routed load and loses none
    m = MoEConfig(num_experts=E, top_k=k, d_expert_ff=F,
                  capacity_factor=2.0)
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    params = {"w_gate": 0.2 * jax.random.normal(ks[0], (E, D, F)),
              "w_up": 0.2 * jax.random.normal(ks[1], (E, D, F)),
              "w_down": 0.2 * jax.random.normal(ks[2], (E, F, D))}
    x = 0.3 * jax.random.normal(ks[3], (N, D))
    C = capacity_for(N, m, E)
    flops_row = 3 * 2 * D * F                    # three GEMMs per row
    dense_fn = jax.jit(lambda b: expert_ffn(params, b, "swiglu"))
    grouped_fn = jax.jit(
        lambda b, t: grouped_expert_ffn(params, b, t, "swiglu"))

    for alpha in ([1.2] if smoke else [0.0, 0.6, 1.2, 2.0]):
        topk = jnp.asarray(zipf_routing(N, E, k, alpha=alpha))
        counts = np.bincount(np.asarray(topk).ravel(), minlength=E)
        dropped = int(np.maximum(counts - C, 0).sum())
        # dense: E fixed-capacity buffers, skew-independent compute
        plan = build_dispatch(topk, E, C)
        buf_d = dispatch_tokens(x, plan, E)
        us_dense = _time(dense_fn, buf_d)
        dense_rows = E * C
        # grouped: compact realized layout (block-aligned ragged groups)
        gd = build_grouped_dispatch(topk, E, block_rows=bn)
        used_rows = int((((counts + bn - 1) // bn) * bn).sum())
        buf_g = dispatch_grouped(x, gd)[:used_rows]
        te = gd.tile_expert[:used_rows // bn]
        us_grouped = _time(grouped_fn, buf_g, te)
        emit(f"moe_dispatch_zipf{alpha:g}", us_grouped,
             f"routed_pairs={N * k};dense_rows={dense_rows};"
             f"grouped_rows={used_rows};dense_dropped={dropped};"
             f"dense_us={us_dense:.1f};"
             f"dense_tpu_us={dense_rows * flops_row / PEAK * 1e6:.4f};"
             f"grouped_tpu_us={used_rows * flops_row / PEAK * 1e6:.4f}")
        # parity: jnp fast path == Pallas kernel == per-expert oracle
        got_jnp = grouped_fn(buf_g, te)
        got_pal = grouped_moe_pallas(buf_g, te, params["w_gate"],
                                     params["w_up"], params["w_down"])
        want = grouped_moe_ref(buf_g, te, params["w_gate"],
                               params["w_up"], params["w_down"])
        err = max(float(jnp.abs(got_jnp - want).max()),
                  float(jnp.abs(got_pal - want).max()))
        assert err < 3e-5, f"grouped parity broke at alpha={alpha}: {err}"
        # dropless invariant: grouped computes every routed pair
        assert used_rows >= N * k, (used_rows, N * k)
        emit(f"moe_dispatch_parity_zipf{alpha:g}", 0.0,
             f"allclose_err={err:.1e}")


def fused_routing_bench(smoke: bool = False) -> None:
    """Single-pass fused routing vs the separate-pass baseline.

    Both paths produce the complete grouped-dispatch metadata a MoE
    layer needs (indices, weights, within-expert ranks, counts, group
    offsets): "reference" runs ``route`` and then the argsort + bincount
    + cumsum plan builder (the pre-fusion front-end); "fused" runs
    ``route_fused``'s one top_k + one-hot cumsum and derives the plan
    arithmetically. Integer outputs must be bit-equal; the fused
    Pallas kernel is parity-checked on the same inputs (interpret-mode
    wall time measures the emulator, so it is not timed). The floor
    assert guards order-of-magnitude regressions in the fused path, not
    microarchitectural noise.
    """
    from repro.config import MoEConfig
    from repro.models.moe import (build_grouped_dispatch,
                                  grouped_dispatch_from_fused, route,
                                  route_fused, route_fused_pallas)

    N, D, E, k = (512, 64, 8, 2) if smoke else (2048, 256, 60, 4)
    m = MoEConfig(num_experts=E, top_k=k, d_expert_ff=4 * D)
    ks = jax.random.split(jax.random.PRNGKey(0), 2)
    x = 0.3 * jax.random.normal(ks[0], (N, D))
    w = jax.random.normal(ks[1], (D, E))

    def ref_path(w, x):
        r = route(w, x, m)
        gd = build_grouped_dispatch(r.topk_idx, E, block_rows=8)
        return r.topk_idx, r.topk_weight, gd

    def fused_path(w, x):
        fr = route_fused(w, x, m)
        gd = grouped_dispatch_from_fused(fr, E, block_rows=8)
        return fr.topk_idx, fr.topk_weight, gd

    ref_fn, fus_fn = jax.jit(ref_path), jax.jit(fused_path)
    us_ref = _time(ref_fn, w, x, reps=10)
    us_fus = _time(fus_fn, w, x, reps=10)

    idx_r, wt_r, gd_r = ref_fn(w, x)
    idx_f, wt_f, gd_f = fus_fn(w, x)
    assert bool((idx_r == idx_f).all()) and bool((wt_r == wt_f).all())
    for gr, gf in zip(jax.tree.leaves(gd_r), jax.tree.leaves(gd_f)):
        assert bool(np.all(np.asarray(gr) == np.asarray(gf))), \
            "fused dispatch plan drifted"
    fr_pal = route_fused_pallas(w, x, m)
    assert bool((fr_pal.topk_idx == idx_f).all())
    assert bool((fr_pal.expert_counts
                 == np.bincount(np.asarray(idx_f).ravel(),
                                minlength=E)).all())

    pairs_ref = N * k / (us_ref * 1e-6)
    pairs_fus = N * k / (us_fus * 1e-6)
    emit("routing_fused", us_fus,
         f"pairs_per_s={pairs_fus:.3e};reference_us={us_ref:.1f};"
         f"speedup={us_ref / us_fus:.2f}x;pallas_parity=exact")
    assert pairs_fus >= 0.5 * pairs_ref, (
        f"fused routing regressed past the floor: "
        f"{pairs_fus:.3e} pairs/s vs reference {pairs_ref:.3e}")


def dump_rows(out_path: str = "BENCH_kernels.json") -> None:
    """Persist every emitted CSV row machine-readable (CI artifact)."""
    from benchmarks.common import ROWS
    with open(out_path, "w") as f:
        json.dump([{"name": n, "us_per_call": u, "derived": d}
                   for n, u, d in ROWS], f, indent=1)
    print(f"wrote {out_path} ({len(ROWS)} rows)")


def run() -> None:
    ks = jax.random.split(jax.random.PRNGKey(0), 4)

    # expert FFN: qwen2-moe-like local tile (4 experts x 512 cap x 2048)
    E, C, D, F = 4, 512, 256, 352
    buf = 0.3 * jax.random.normal(ks[0], (E, C, D))
    wg = 0.2 * jax.random.normal(ks[1], (E, D, F))
    wu = 0.2 * jax.random.normal(ks[2], (E, D, F))
    wd = 0.2 * jax.random.normal(ks[3], (E, F, D))
    us = _time(lambda *a: expert_ffn_pallas(*a), buf, wg, wu, wd)
    ref = expert_ffn_ref(buf, wg, wu, wd)
    got = expert_ffn_pallas(buf, wg, wu, wd)
    err = float(jnp.abs(got - ref).max())
    flops = 2 * 3 * E * C * D * F
    emit("kernel_expert_ffn", us,
         f"allclose_err={err:.1e};tpu_us_at_peak={flops / PEAK * 1e6:.2f}")

    # router top-k: 60-expert qwen2-moe router
    N, Dr, Er, k = 2048, 256, 60, 4
    x = jax.random.normal(ks[0], (N, Dr))
    w = jax.random.normal(ks[1], (Dr, Er))
    us = _time(lambda *a: router_topk_pallas(*a, k=k), x, w)
    vals, idx = router_topk_pallas(x, w, k=k)
    rv, ri = router_topk_ref(x, w, k)
    emit("kernel_router_topk", us,
         f"idx_match={bool((idx == ri).all())};"
         f"tpu_us_at_peak={2 * N * Dr * Er / PEAK * 1e6:.2f}")

    # decode attention: 32k cache tile
    B, Nh, G, Dh, T = 1, 2, 4, 128, 8192
    q = jax.random.normal(ks[0], (B, Nh, G, Dh))
    kc = jax.random.normal(ks[1], (B, T, Nh, Dh))
    vc = jax.random.normal(ks[2], (B, T, Nh, Dh))
    us = _time(lambda *a: decode_attention_pallas(*a, T - 5), q, kc, vc)
    got = decode_attention_pallas(q, kc, vc, T - 5)
    ref = decode_attention_ref(q, kc, vc, T - 5)
    err = float(jnp.abs(got - ref).max())
    hbm_bytes = 2 * B * T * Nh * Dh * 4
    emit("kernel_decode_attention", us,
         f"allclose_err={err:.1e};"
         f"tpu_us_at_hbm_bw={hbm_bytes / 819e9 * 1e6:.2f}")

    # grouped MoE kernel: same local tile, heavily skewed realized load
    counts = (C + C // 2, C // 4, C // 4, 0)
    rows = int(sum(-(-c // 128) * 128 for c in counts))
    xg_parts, tiles = [], []
    for e, c in enumerate(counts):
        if c == 0:
            continue
        pad = (-c) % 128
        xg_parts.append(0.3 * jax.random.normal(
            jax.random.fold_in(ks[0], e), (c, D)))
        if pad:
            xg_parts.append(jnp.zeros((pad, D)))
        tiles += [e] * ((c + pad) // 128)
    xg = jnp.concatenate(xg_parts)
    te = jnp.asarray(tiles, jnp.int32)
    us = _time(lambda *a: grouped_moe_pallas(*a), xg, te, wg, wu, wd)
    err = float(jnp.abs(grouped_moe_pallas(xg, te, wg, wu, wd)
                        - grouped_moe_ref(xg, te, wg, wu, wd)).max())
    flops = 2 * 3 * rows * D * F
    emit("kernel_grouped_moe", us,
         f"allclose_err={err:.1e};rows={rows};"
         f"tpu_us_at_peak={flops / PEAK * 1e6:.2f}")

    moe_dispatch_sweep()
    fused_routing_bench()


if __name__ == "__main__":
    if "--smoke" in sys.argv[1:]:
        moe_dispatch_sweep(smoke=True)
        fused_routing_bench(smoke=True)
    else:
        run()
    dump_rows()
