"""Incremental vs full re-planning over a layer-sparse drifting trace.

Drives the trace control loop twice over the SAME bursty Zipf trace
whose popularity drift is LAYER-SPARSE (each window only a couple of
layers shift while the rest hold still) — the fleet regime where
re-running the full Alg. 1 per-method grid on every re-plan wastes
almost all of its work on layers whose deployment rows are still right:

* **full** — the historical loop: every feedback re-plan re-solves all
  ``L`` layers for every comm method (including method 1's global beta
  search);
* **incremental** — :class:`~repro.plan.incremental.IncrementalODSPlanner`
  with drift threshold ``delta``: only layers whose demand moved more
  than ``delta`` (relative L1) are re-solved; unshifted layers splice
  their cached rows, and the loop itself skips re-plans when no layer
  drifted.

Rows report the mean per-re-plan planning wall-clock, total billed
GB-seconds, and re-plan counts per configuration. Results land
machine-readable in ``BENCH_replan.json``. ``--smoke`` (CI) additionally
ASSERTS the acceptance contract: incremental re-planning cuts the mean
per-window planning wall-clock by >= 3x while the final billed
GB-seconds stay within 2% of full re-planning.

Pure numpy (no JAX model) so the suite runs in seconds.

Usage:
    PYTHONPATH=src:. python benchmarks/run.py --only replan_bench
    PYTHONPATH=src:. python benchmarks/replan_bench.py [--smoke] [--out F]
"""
from __future__ import annotations

import json

import numpy as np

from benchmarks.common import emit
from repro.core.costmodel import ModelProfile, PlatformSpec
from repro.core.simulator import FaultProfile, ServerlessSimulator
from repro.plan.backends import run_plan_over_trace
from repro.plan.incremental import IncrementalODSPlanner
from repro.plan.planner import get_planner
from repro.predict import OnlinePredictor
from repro.traces import bursty_arrivals, demand_trace, drift_popularity, \
    zipf_popularity

# fleet-scale layer count: full re-plans pay L x (methods x beta grid)
PROF = ModelProfile(
    num_moe_layers=16, experts_per_layer=8,
    expert_param_bytes=28e6, token_in_bytes=3072.0, token_out_bytes=3072.0,
    u_ref_s=2e-4, intermediate_bytes=4e6, nonmoe_param_bytes=9e6)

# binding payload cap (the paper-regime scaling, see common.py) so the
# Alg. 2 feedback cases actually fire and force re-plans
SPEC = PlatformSpec(payload_mb=0.4)

FAULTS = FaultProfile(cold_start_prob=0.8, warm_pool=2)

DELTA = 0.02

# the volatile minority: only these layers' popularity drifts; the other
# 14 layers' routing holds still (re-solving them is pure waste)
VOLATILE = (3, 11)


def _layer_sparse_trace(steps: int):
    """Bursty trace where only the ``VOLATILE`` layers take drift steps;
    every other layer keeps its Zipf popularity for the whole trace."""
    pop = zipf_popularity(PROF.num_moe_layers, PROF.experts_per_layer,
                          seed=0)
    pops = []
    for nxt in drift_popularity(pop, steps, drift=0.5, seed=2):
        cur = pop.copy()
        for layer in VOLATILE:
            cur[layer] = nxt[layer]
        pops.append(cur)
    arr = np.maximum(bursty_arrivals(1.0, steps, burst_mult=8.0, seed=1), 1)
    arr[2::4] = 8                              # periodic guaranteed bursts
    return demand_trace(arr, pops, tokens_per_request=200)


def _run(trace, planner, *, delta=None):
    predictor = OnlinePredictor(PROF.num_moe_layers,
                                PROF.experts_per_layer, 16, decay=0.7)
    plan = planner.plan(trace.windows[0].demand, PROF, SPEC, t_limit_s=1e9)
    sim = ServerlessSimulator(PROF, SPEC, seed=7, faults=FAULTS)
    out = run_plan_over_trace(
        plan, trace, sim, PROF, SPEC,
        plan_fn=lambda d, **kw: planner.plan(d, PROF, SPEC, t_limit_s=1e9,
                                             **kw),
        predictor=predictor, prewarm="predicted", delta=delta)
    reps = out["reports"]
    planning = np.asarray(out["planning_s"], float)
    n = len(trace)
    return {
        "cost": float(sum(r.billed_cost for r in reps)),
        "replans": int(out["replans"]),
        "replans_skipped": int(out["replans_skipped"]),
        "planning_total_s": float(planning.sum()),
        "planning_mean_s": float(planning.sum() / n),
        "planning_max_s": float(planning.max()),
    }


def run(smoke: bool = False, out_path: str = "BENCH_replan.json") -> None:
    steps = 12 if smoke else 32
    trace = _layer_sparse_trace(steps)

    full = _run(trace, get_planner("ods"))
    emit("replan_full", full["planning_mean_s"] * 1e6,
         f"cost=${full['cost']:.6f} replans={full['replans']} "
         f"plan_total={full['planning_total_s'] * 1e3:.1f}ms")

    inc = _run(trace, IncrementalODSPlanner(delta=DELTA), delta=DELTA)
    emit("replan_incremental", inc["planning_mean_s"] * 1e6,
         f"cost=${inc['cost']:.6f} replans={inc['replans']} "
         f"skipped={inc['replans_skipped']} "
         f"plan_total={inc['planning_total_s'] * 1e3:.1f}ms")

    speedup = full["planning_mean_s"] / max(inc["planning_mean_s"], 1e-12)
    parity = abs(inc["cost"] - full["cost"]) / full["cost"]
    results = {"full": full, "incremental": inc, "delta": DELTA,
               "windows": steps, "planning_speedup": speedup,
               "gb_s_gap_frac": parity}
    with open(out_path, "w") as f:
        json.dump(results, f, indent=1, sort_keys=True)
    emit("replan_speedup", 0.0,
         f"planning {speedup:.1f}x faster, billed gap "
         f"{100 * parity:.2f}% -> {out_path}")

    if smoke:
        # acceptance contract: incremental re-planning cuts mean
        # per-window planning wall-clock >= 3x at <= 2% billed parity
        assert full["replans"] >= 2, full["replans"]
        assert speedup >= 3.0, speedup
        assert parity <= 0.02, parity
        print("replan_smoke,0.0,ok")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="reduced scales for CI + acceptance asserts")
    ap.add_argument("--out", default="BENCH_replan.json",
                    help="machine-readable results path")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(smoke=args.smoke, out_path=args.out)
