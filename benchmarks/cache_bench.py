"""Expert-weight caching under bursty sparse-drift traffic: cache size x
packing degree, versus the PR-5 prewarm-only configuration.

Drives the simulator over a bursty Zipf-drift trace whose per-window
popularity is SPARSE (only the top few experts per layer see traffic, and
the active set drifts) — the regime where speculative pre-warming pays
recurring rent (keep-alive on forecast misses, cold boots on re-entrant
experts) while a persistent residency cache serves re-entrants with hits
and cheap swaps:

* **prewarm-only** — the PR-5 configuration: ``OnlinePredictor`` +
  ``prewarm="predicted"``, no cache;
* **cache sweep** — the same predictor driving a
  :class:`~repro.expcache.ContainerCacheModel` (eviction + swap targets
  from the forecast), swept over ``weight_frac`` (container cache size)
  x ``packing_degree`` (long-tail co-residency).

Rows report billed cost, cold starts, residency hits/swaps, swap and
keep-alive GB-seconds, and the worst-window (p99) latency per
configuration. Results also land machine-readable in ``BENCH_cache.json``.
``--smoke`` (CI) additionally ASSERTS the acceptance contract: the
predictor-driven cache strictly reduces total billed GB-seconds versus
prewarm-only and does not regress p99 latency.

Pure numpy (no JAX model) so the suite runs in seconds.

Usage:
    PYTHONPATH=src:. python benchmarks/run.py --only cache_bench
    PYTHONPATH=src:. python benchmarks/cache_bench.py [--smoke] [--out F]
"""
from __future__ import annotations

import json
import time

import numpy as np

from benchmarks.common import emit
from repro.core.costmodel import ModelProfile, PlatformSpec
from repro.core.simulator import FaultProfile, ServerlessSimulator
from repro.expcache import CacheConfig, ContainerCacheModel
from repro.plan.backends import run_plan_over_trace
from repro.plan.planner import get_planner
from repro.predict import OnlinePredictor
from repro.traces import (bursty_arrivals, demand_trace, drift_popularity,
                          zipf_popularity)

SPEC = PlatformSpec()
PROF = ModelProfile(
    num_moe_layers=4, experts_per_layer=8,
    expert_param_bytes=28e6, token_in_bytes=3072.0, token_out_bytes=3072.0,
    u_ref_s=2e-4, intermediate_bytes=4e6, nonmoe_param_bytes=9e6)

FAULTS = FaultProfile(cold_start_prob=0.8, warm_pool=2)


def _sparse_drift_trace(steps: int, keep: int = 4):
    """Per-window popularity keeps only the top-``keep`` experts per
    layer: experts flicker in and out of the active set under drift."""
    pop = zipf_popularity(PROF.num_moe_layers, PROF.experts_per_layer,
                          seed=0)
    pops = []
    for p in drift_popularity(pop, steps, drift=0.35, seed=2):
        q = p.copy()
        for layer in range(q.shape[0]):
            order = np.argsort(q[layer])[::-1]
            q[layer, order[keep:]] = 0.0
            q[layer] /= q[layer].sum()
        pops.append(q)
    arr = np.maximum(bursty_arrivals(1.0, steps, burst_mult=8.0, seed=1), 1)
    return demand_trace(arr, pops, tokens_per_request=100)


def _run(plan, trace, *, prewarm=None, cache_config=None):
    predictor = OnlinePredictor(PROF.num_moe_layers,
                                PROF.experts_per_layer, 16, decay=0.7)
    sim = ServerlessSimulator(PROF, SPEC, seed=7, faults=FAULTS)
    cache = None
    if cache_config is not None:
        cache = ContainerCacheModel.from_plan(plan, PROF, SPEC,
                                              config=cache_config)
    t0 = time.perf_counter()
    out = run_plan_over_trace(plan, trace, sim, PROF, SPEC,
                              predictor=predictor, prewarm=prewarm,
                              cache=cache)
    us = (time.perf_counter() - t0) * 1e6
    reps = out["reports"]
    lat = np.array([r.latency_s for r in reps])
    return us, {
        "cost": float(sum(r.billed_cost for r in reps)),
        "cold": int(sum(r.cold_starts for r in reps)),
        "hits": int(sum(r.cache_hits for r in reps)),
        "swaps": int(sum(r.cache_swaps for r in reps)),
        "swap_gb_s": float(sum(r.swap_gb_s for r in reps)),
        "keepalive_gb_s": float(sum(r.cache_keepalive_gb_s
                                    for r in reps)),
        "wasted_prewarm_gb_s": float(sum(r.wasted_prewarm_gb_s
                                         for r in reps)),
        "packed_experts": int(max(r.packed_experts for r in reps)),
        "p99_latency_s": float(np.percentile(lat, 99)),
    }


def run(smoke: bool = False, out_path: str = "BENCH_cache.json") -> None:
    steps = 10 if smoke else 24
    trace = _sparse_drift_trace(steps)
    plan = get_planner("ods").plan(trace.windows[0].demand, PROF, SPEC,
                                   t_limit_s=1e9)

    us, base = _run(plan, trace, prewarm="predicted")
    emit("cache_prewarm_only", us,
         f"cost=${base['cost']:.6f} cold={base['cold']} "
         f"wasted_gb_s={base['wasted_prewarm_gb_s']:.3f} "
         f"p99={base['p99_latency_s']:.2f}s")

    weight_fracs = (0.7,) if smoke else (0.5, 0.7, 0.9)
    degrees = (1, 2) if smoke else (1, 2, 4)
    results = {"prewarm_only": base, "sweep": []}
    best = None
    for wf in weight_fracs:
        for deg in degrees:
            cfg = CacheConfig(policy="predictor", weight_frac=wf,
                              packing_degree=deg,
                              pack_threshold_frac=0.12)
            us, r = _run(plan, trace, cache_config=cfg)
            name = f"cache_wf{wf:g}_deg{deg}"
            emit(name, us,
                 f"cost=${r['cost']:.6f} cold={r['cold']} "
                 f"hits={r['hits']} swaps={r['swaps']} "
                 f"ka_gb_s={r['keepalive_gb_s']:.3f} "
                 f"packed={r['packed_experts']} "
                 f"p99={r['p99_latency_s']:.2f}s")
            row = dict(weight_frac=wf, packing_degree=deg, **r)
            results["sweep"].append(row)
            if best is None or r["cost"] < best["cost"]:
                best = row
    results["best"] = best
    results["saving_vs_prewarm_only"] = 1.0 - best["cost"] / base["cost"]
    with open(out_path, "w") as f:
        json.dump(results, f, indent=1, sort_keys=True)
    emit("cache_best", 0.0,
         f"wf={best['weight_frac']:g} deg={best['packing_degree']} "
         f"saves {100 * results['saving_vs_prewarm_only']:.1f}% "
         f"-> {out_path}")

    if smoke:
        # acceptance contract: predictor-driven caching + packing
        # strictly reduces billed GB-seconds vs the PR-5 prewarm-only
        # configuration without regressing p99 latency
        assert best["cost"] < base["cost"], (best["cost"], base["cost"])
        assert best["p99_latency_s"] <= base["p99_latency_s"], \
            (best["p99_latency_s"], base["p99_latency_s"])
        assert best["hits"] > 0
        packed = [r for r in results["sweep"] if r["packing_degree"] > 1]
        assert any(r["packed_experts"] > 0 for r in packed)
        print("cache_smoke,0.0,ok")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="reduced scales for CI + acceptance asserts")
    ap.add_argument("--out", default="BENCH_cache.json",
                    help="machine-readable results path")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(smoke=args.smoke, out_path=args.out)
