"""Fig. 13: BO acquisition comparison — ratio of billed cost (and expert
prediction difference) after BO with each acquisition, relative to no BO.

Acquisitions: ours (multi-dim eps-GS), single-eps GS, random, TPE.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, paper_regime_spec, small_runtime
from repro.core.predictor import ExpertPredictor

ACQS = ("multi_eps", "single_eps", "random", "tpe")


def run(max_iters: int = 5) -> None:
    for arch in ("bert-moe", "gpt2-moe"):
        # paper-faithful MAP demand + thin profile: prediction errors leave
        # the BO room to improve (the expected-mode planner is near-oracle
        # at this scale, which would flatline every acquisition)
        rt = small_runtime(arch, jitter=0.03, demand_mode="map",
                           profile_batches=2, slo_s=8.0,
                           spec=paper_regime_spec())
        rt.profile_table()
        eval_fn = rt.make_eval_fn()
        base = eval_fn(rt.table)              # no-BO trial
        b = rt.learn_batches()[0]
        real = rt.real_demand(b)
        p0 = ExpertPredictor(rt.table, top_k=rt.top_k).fit()
        diff0 = p0.prediction_difference(
            p0.predict_demand(b, mode="map"), real)
        emit(f"fig13_{arch}_no_bo", 0.0,
             f"cost=${base.cost:.6f};diff={diff0:.2f}")
        for acq in ACQS:
            t0 = time.perf_counter()
            res = rt.run_bo(Q=40, max_iters=max_iters, acquisition=acq,
                            seed=3)
            us = (time.perf_counter() - t0) * 1e6 / max(res.iterations, 1)
            pb = ExpertPredictor(res.best_table, top_k=rt.top_k).fit()
            diffb = pb.prediction_difference(
                pb.predict_demand(b, mode="map"), real)
            emit(f"fig13_{arch}_{acq}", us,
                 f"cost_ratio={res.best_cost / max(base.cost, 1e-12):.4f};"
                 f"diff_ratio={diffb / max(diff0, 1e-9):.4f};"
                 f"iters={res.iterations}")


if __name__ == "__main__":
    run()
