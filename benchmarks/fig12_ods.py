"""Fig. 12: billed cost of ODS vs joint-MIQCP vs random deployment across
inference-throughput targets.

"MIQCP" here is the single-method exact solver forced to ONE method for all
layers (the paper's monolithic-solver baseline: no per-layer mixing);
ODS mixes methods per layer under the SLO (Alg. 1).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, plan_with
from repro.core import comm
from repro.core.costmodel import ModelProfile, PlatformSpec

SPEC = PlatformSpec()
PROF = ModelProfile(
    num_moe_layers=12, experts_per_layer=4,
    expert_param_bytes=3 * 768 * 3072 * 4.0,
    token_in_bytes=768 * 4.0, token_out_bytes=768 * 4.0,
    u_ref_s=1.2e-4, intermediate_bytes=4e6, nonmoe_param_bytes=9e6)

N_TOKENS = 10_240


def _demand(seed=0):
    rng = np.random.default_rng(seed)
    zipf = (1.0 / np.arange(1, 5)) ** 1.2
    base = N_TOKENS * zipf / zipf.sum()
    return np.stack([rng.permutation(base) for _ in range(12)])


def run() -> None:
    from repro.core.deployment import ods
    from repro.plan.planner import get_planner

    d = _demand()
    planner = get_planner("ods")
    for tput_target in (5, 10, 20, 40):
        t_limit = N_TOKENS / tput_target
        t0 = time.perf_counter()
        # the per-method exact solutions are shared between the ODS mix
        # and the single-method baselines (one solve per method)
        sols = planner.solutions(d, PROF, SPEC)
        pol = ods(sols, d, PROF, SPEC, t_limit_s=t_limit)
        us = (time.perf_counter() - t0) * 1e6
        emit(f"fig12_ods_tput{tput_target}", us,
             f"cost=${pol.total_cost:.4f};slo_met={pol.meets_slo}")
        # single-method joint solver (no per-layer mixing)
        best = min((np.where(np.isfinite(s.layer_cost), s.layer_cost,
                             1e12).sum(), a) for a, s in sols.items())
        emit(f"fig12_miqcp_single_tput{tput_target}", us,
             f"cost=${best[0]:.4f};method={best[1]}")
        rnd = plan_with("random", d, PROF, SPEC, seed=1)
        emit(f"fig12_random_tput{tput_target}", 0.0,
             f"cost=${rnd.total_cost:.4f}")


if __name__ == "__main__":
    run()
