"""Shared benchmark plumbing.

Every benchmark prints ``name,us_per_call,derived`` CSV rows (per the
harness contract). ``us_per_call`` is the wall-time of the measured
operation; ``derived`` carries the figure's metric (cost ratio, tokens/s,
prediction difference, ...).
"""
from __future__ import annotations

import time
from contextlib import contextmanager
from typing import Iterator, List, Tuple

ROWS: List[Tuple[str, float, str]] = []


def emit(name: str, us_per_call: float, derived) -> None:
    row = (name, us_per_call, str(derived))
    ROWS.append(row)
    print(f"{name},{us_per_call:.1f},{derived}")


@contextmanager
def timed(name: str, derived_fn=lambda: "") -> Iterator[None]:
    t0 = time.perf_counter()
    yield
    emit(name, (time.perf_counter() - t0) * 1e6, derived_fn())


def small_runtime(arch: str = "gpt2-moe", *, spec=None, **over):
    """A reduced-scale ``ServerlessMoERuntime`` (planner selectable via
    ``planner="ods"|"fixed-N"|...``, see ``repro.plan.planner``)."""
    from repro.core.runtime import RuntimeConfig, ServerlessMoERuntime
    kw = dict(arch=arch, profile_batches=4, learn_batches=1, eval_batches=2,
              seq_len=64, batch_size=4)
    kw.update(over)
    return ServerlessMoERuntime(RuntimeConfig(**kw), spec=spec)


def plan_with(planner_name: str, demand, prof, spec, *,
              t_limit_s: float = float("inf"), seed: int = 0, **planner_kw):
    """Registry-based planning shorthand for benchmarks: name -> plan."""
    from repro.plan.planner import get_planner
    return get_planner(planner_name, **planner_kw).plan(
        demand, prof, spec, t_limit_s=t_limit_s, seed=seed)


def paper_regime_spec():
    """PlatformSpec with the payload cap scaled to the bench's token scale.

    The paper serves 10240-token batches where a hot expert's input
    (~7.9 MB) exceeds the 6 MB payload (Fig. 4) — that binding constraint
    is where expert-selection prediction pays. Our CPU-scale batches are
    ~40x smaller, so the cap is scaled to keep r*D_in / D^p ~ 1.3.
    """
    from repro.core.costmodel import PlatformSpec
    return PlatformSpec(payload_mb=0.4)
