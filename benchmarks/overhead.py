"""§V-F: algorithm overhead — profiling, prediction, ODS, BO iteration.

The paper reports (at full scale on their testbed): profiling ~28.89 s /
100 batches, prediction ~20.31 s / 10 batches, ODS ~2.27 s, BO ~62.15 s
per iteration. Our numbers are at reduced scale; the derived field carries
the per-unit cost so the scaling is visible.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, small_runtime
from repro.core.predictor import ExpertPredictor


def run() -> None:
    rt = small_runtime("gpt2-moe", profile_batches=4)
    t0 = time.perf_counter()
    rt.profile_table()
    prof_s = time.perf_counter() - t0
    emit("overhead_profiling", prof_s * 1e6,
         f"{prof_s / 4:.2f}s_per_batch")

    p = ExpertPredictor(rt.table, top_k=rt.top_k).fit()
    b = rt.learn_batches()[0]
    t0 = time.perf_counter()
    p.predict_demand(b)
    pred_s = time.perf_counter() - t0
    emit("overhead_prediction", pred_s * 1e6, f"{pred_s:.2f}s_per_batch")

    pred = ExpertPredictor(rt.table, top_k=rt.top_k).fit()
    dem = pred.predict_demand(b)
    t0 = time.perf_counter()
    rt.plan(dem)
    ods_s = time.perf_counter() - t0
    emit("overhead_ods_3solvers", ods_s * 1e6, f"{ods_s:.2f}s")

    eval_fn = rt.make_eval_fn()
    t0 = time.perf_counter()
    eval_fn(rt.table)
    it_s = time.perf_counter() - t0
    emit("overhead_bo_iteration", it_s * 1e6, f"{it_s:.2f}s_per_iter")


if __name__ == "__main__":
    run()
