"""Fig. 11: billed cost + throughput of the three scatter-gather designs.

Evaluates the Eq. 3-11 time models at the paper's operating points
(256 vs 2560-token batches, 3008 MB functions, no replicas) for a Bert-MoE-
scale expert. Direct transfer must win small batches; indirect (pipelined)
must win large ones; direct becomes infeasible past the payload cap.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timed
from repro.core import comm
from repro.core.costmodel import ModelProfile, PlatformSpec

SPEC = PlatformSpec()
PROF = ModelProfile(
    num_moe_layers=12, experts_per_layer=4,
    expert_param_bytes=3 * 768 * 3072 * 4.0,
    token_in_bytes=768 * 4.0, token_out_bytes=768 * 4.0,
    u_ref_s=1.2e-4, intermediate_bytes=4e6, nonmoe_param_bytes=9e6)


def run() -> None:
    E = 4
    for n_tokens in (256, 2560, 10240):
        r = np.full(E, n_tokens / E, float)
        g = np.ones(E)
        mem = np.full(E, 3008.0)
        for a, label in ((1, "pipelined_indirect"), (2, "indirect"),
                         (3, "direct")):
            beta = max(min(n_tokens // E // 4, 1024), 1) if a == 1 else 1
            times = comm.layer_times(a, r, g, mem, beta, PROF, SPEC)
            cost = comm.layer_billed_cost(times, mem, SPEC) * 12  # 12 layers
            feasible = bool(times.feasible.all())
            tput = n_tokens / (12 * times.t_latency) if feasible else 0.0
            emit(f"fig11_{n_tokens}tok_{label}",
                 times.t_latency * 1e6,
                 f"cost=${cost:.6f};tput={tput:.1f}t/s;feasible={feasible}")


if __name__ == "__main__":
    run()
