"""Real multi-process scatter-gather vs the Eq. 3-11 closed forms.

Runs a plan through :class:`~repro.dist.DistributedBackend` on BOTH
transports and compares, per MoE layer, the measured wave makespan
against the closed-form prediction the planner optimized
(``predicted_rep_max_s``: the Eq. 6 head/block/tail decomposition of the
slowest replica, scaled to model seconds):

* ``dist_inline_L*`` — the zero-latency oracle; rel. error pins at ~0.
* ``dist_process_L*`` — real spawn-context workers under time-dilated
  emulation (``time_scale`` wall seconds per model second); rel. error
  is the IPC + sleep-granularity overhead the calibrated tolerance in
  ``tests/test_distributed_backend.py`` (``GB_S_TOL``) budgets for.

Each row's ``derived`` field reports ``rel_err`` (measured vs predicted
makespan) and ``overlap`` — worker-utilization overlap efficiency,
``busy_sum / (makespan * workers)``: how much of the wave's wall clock
the fleet spent computing/holding chunks rather than idling on skew or
gather barriers. Aggregate rows compare total billed GB-seconds.

``--smoke`` (CI): 2 workers, the tiny 3x4 model, a hard ``SIGALRM``
timeout, and ASSERTS the acceptance contract — inline exact, process
billed cost within tolerance, all chunk outputs verified.

Usage:
    PYTHONPATH=src:. python benchmarks/run.py --only distributed_bench
    PYTHONPATH=src:. python benchmarks/distributed_bench.py [--smoke]
"""
from __future__ import annotations

import signal
import time

import numpy as np

from benchmarks.common import emit
from repro.core.costmodel import ModelProfile, PlatformSpec
from repro.core.simulator import ServerlessSimulator
from repro.dist import DistributedBackend
from repro.plan.planner import get_planner

SPEC = PlatformSpec()
PROF = ModelProfile(
    num_moe_layers=3, experts_per_layer=4,
    expert_param_bytes=28e6, token_in_bytes=3072.0, token_out_bytes=3072.0,
    u_ref_s=2e-4, intermediate_bytes=4e6, nonmoe_param_bytes=9e6)

GB_S_TOL = 0.15        # mirrors tests/test_distributed_backend.py
SMOKE_TIMEOUT_S = 120  # hard wall-clock cap for the CI leg


def _demand(tokens: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    d = rng.zipf(1.5, size=(PROF.num_moe_layers,
                            PROF.experts_per_layer)).astype(float)
    return d / d.sum(axis=1, keepdims=True) * tokens


def _layer_rows(tag: str, rep) -> float:
    """Emit one row per MoE layer; return the worst relative error."""
    worst = 0.0
    for li in rep.extras["layers"]:
        pred = li["predicted_rep_max_s"]
        meas = li["measured_makespan_s"]
        if pred <= 0:
            continue
        rel = abs(meas - pred) / pred
        worst = max(worst, rel)
        workers = max(rep.extras["num_workers"], 1)
        overlap = li["busy_sum_s"] / max(meas * workers, 1e-12)
        emit(f"{tag}_L{li['layer']}", meas * 1e6,
             f"rel_err={rel:.4f} overlap={overlap:.3f} "
             f"msgs={li['chunk_msgs']} beta={li['beta']}")
    return worst


def _run(transport: str, tokens: int, *, workers: int,
         time_scale: float) -> tuple:
    demand = _demand(tokens)
    plan = get_planner("ods").plan(demand, PROF, SPEC)
    want = ServerlessSimulator(PROF, SPEC).run(plan, demand, tokens)
    with DistributedBackend(PROF, SPEC, transport=transport,
                            num_workers=workers,
                            time_scale=time_scale) as be:
        t0 = time.perf_counter()
        got = be.run(plan, demand, tokens)
        wall = time.perf_counter() - t0
    tag = f"dist_{transport}"
    worst = _layer_rows(tag, got)
    cost_rel = abs(got.billed_cost - want.billed_cost) \
        / max(want.billed_cost, 1e-12)
    emit(f"{tag}_total", wall * 1e6,
         f"cost_rel_err={cost_rel:.4f} worst_layer_rel={worst:.4f} "
         f"verified={got.extras['verified_chunks']} "
         f"mismatches={got.extras['output_mismatches']}")
    return got, want, cost_rel


def run(smoke: bool = False) -> None:
    tokens = 256 if smoke else 1024
    workers = 2 if smoke else 4
    inline, _, inline_rel = _run("inline", tokens, workers=workers,
                                 time_scale=0.05)
    # time_scale stays at the calibrated 0.05 even in smoke: shrinking
    # it further makes fixed IPC overhead dominate the tiny chunk
    # budgets and blows the tolerance
    proc, _, proc_rel = _run("process", tokens, workers=workers,
                             time_scale=0.05)
    if smoke:
        assert inline_rel < 1e-9, \
            f"inline transport must be exact, got rel err {inline_rel}"
        assert proc_rel < GB_S_TOL, \
            f"process billed-cost rel err {proc_rel} > {GB_S_TOL}"
        for rep in (inline, proc):
            assert rep.extras["output_mismatches"] == 0
            assert rep.extras["verified_chunks"] > 0
        print(f"SMOKE OK: inline exact, process rel err "
              f"{proc_rel:.4f} < {GB_S_TOL}")


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny model, 2 workers, hard timeout, asserts")
    args = ap.parse_args()
    if args.smoke:
        # hard backstop: a hung worker/pipe must fail CI fast, not eat
        # the job's budget
        signal.signal(signal.SIGALRM, lambda *_: (_ for _ in ()).throw(
            TimeoutError(f"smoke exceeded {SMOKE_TIMEOUT_S}s")))
        signal.alarm(SMOKE_TIMEOUT_S)
    run(smoke=args.smoke)
    if args.smoke:
        signal.alarm(0)


if __name__ == "__main__":
    main()
