"""Multi-tenant consolidation: one shared fleet vs N independent fleets.

A bursty latency-bound tenant (p99 target) and a diurnal cost-bound
tenant share one expert pool. The shared configuration plans the POOLED
demand through ``MultiTenantPlanner`` (joint SLO = the tightest
latency-bound tenant's p99 target, per-tenant cache residency quotas,
per-tenant billing attribution); the baseline plans, simulates, and
bills each tenant on its OWN fleet (``run_tenants_independently``, with
the concurrent-fleet wall-clock merge).

Rows report total billed GB-seconds, the per-tenant p99 per-window
latency, and the planner's consolidation-savings estimate. Results land
machine-readable in ``BENCH_tenancy.json``. ``--smoke`` (CI)
additionally ASSERTS the acceptance contract: the shared fleet bills
strictly fewer GB-seconds than the independent fleets while NO
latency-bound tenant's p99 regresses past its SLO target.

Pure numpy (no JAX model) so the suite runs in seconds.

Usage:
    PYTHONPATH=src:. python benchmarks/run.py --only tenancy_bench
    PYTHONPATH=src:. python benchmarks/tenancy_bench.py [--smoke] [--out F]
"""
from __future__ import annotations

import json

import numpy as np

from benchmarks.common import emit
from repro.core.costmodel import ModelProfile, PlatformSpec
from repro.core.simulator import FaultProfile
from repro.plan.tenancy import (MultiTenantPlanner,
                                run_tenants_independently,
                                run_tenants_over_traces)
from repro.traces import mixed_tenant_pair

SPEC = PlatformSpec()
PROF = ModelProfile(
    num_moe_layers=4, experts_per_layer=8,
    expert_param_bytes=28e6, token_in_bytes=3072.0, token_out_bytes=3072.0,
    u_ref_s=2e-4,           # pinned: bench numerics must not depend on
    #                         wall-clock calibration
    intermediate_bytes=4e6, nonmoe_param_bytes=9e6)

FAULTS = FaultProfile(cold_start_prob=0.3, warm_pool=1,
                      straggler_prob=0.05, concurrency_limit=8)


def _tenant_rows(name: str, merged) -> dict:
    out = {}
    for tname, blk in merged.tenants.items():
        out[tname] = {
            "billed_cost": blk["billed_cost"],
            "p99_latency_s": blk["p99_latency_s"],
            "max_latency_s": blk["max_latency_s"],
            "num_tokens": blk["num_tokens"],
            "cold_starts": blk["cold_starts"],
        }
        emit(f"tenancy_{name}_{tname}", 0.0,
             f"cost=${blk['billed_cost']:.6f} "
             f"p99={blk['p99_latency_s']:.2f}s "
             f"cold={blk['cold_starts']}")
    return out


def run(smoke: bool = False, out_path: str = "BENCH_tenancy.json") -> None:
    steps = 8 if smoke else 24
    tenants = list(mixed_tenant_pair(PROF.num_moe_layers,
                                     PROF.experts_per_layer,
                                     steps=steps, seed=0))
    slos = {t.name: t.slo for t in tenants}

    planner = MultiTenantPlanner(tenants)
    shared = run_tenants_over_traces(tenants, PROF, SPEC, planner=planner,
                                     seed=0, faults=FAULTS, cache="lru")
    s_merged = shared["merged"]
    meta = shared["final_plan"].metadata.get("tenants", {})
    emit("tenancy_shared_total",
         float(np.mean(shared["planning_s"])) * 1e6,
         f"cost=${s_merged.billed_cost:.6f} replans={shared['replans']} "
         f"savings_est=${meta.get('consolidation_savings', 0.0):.6f}")
    s_tenants = _tenant_rows("shared", s_merged)

    indep = run_tenants_independently(tenants, PROF, SPEC, seed=0,
                                      faults=FAULTS, cache="lru")
    i_merged = indep["merged"]
    emit("tenancy_independent_total", 0.0,
         f"cost=${i_merged.billed_cost:.6f} "
         f"wall={i_merged.extras.get('wall_clock_s', 0.0):.1f}s")
    i_tenants = _tenant_rows("independent", i_merged)

    saving = 1.0 - s_merged.billed_cost / max(i_merged.billed_cost, 1e-12)
    results = {
        "windows": steps,
        "shared": {"billed_cost": s_merged.billed_cost,
                   "replans": shared["replans"],
                   "planner_meta": meta,
                   "tenants": s_tenants},
        "independent": {"billed_cost": i_merged.billed_cost,
                        "wall_clock_s": i_merged.extras.get(
                            "wall_clock_s", 0.0),
                        "tenants": i_tenants},
        "slos": {n: {"kind": s.kind, "p99_target_s": s.p99_target_s}
                 for n, s in slos.items()},
        "consolidation_saving_frac": saving,
    }
    with open(out_path, "w") as f:
        json.dump(results, f, indent=1, sort_keys=True)
    emit("tenancy_consolidation", 0.0,
         f"shared bills {100 * saving:.1f}% fewer GB-s -> {out_path}")

    if smoke:
        # acceptance contract: consolidation saves GB-seconds AND no
        # latency-bound tenant's p99 regresses past its SLO target
        assert s_merged.billed_cost < i_merged.billed_cost, \
            (s_merged.billed_cost, i_merged.billed_cost)
        for name, slo in slos.items():
            if slo.kind != "latency":
                continue
            p99 = s_merged.tenants[name]["p99_latency_s"]
            assert p99 <= slo.p99_target_s, \
                f"{name}: p99 {p99:.2f}s > SLO {slo.p99_target_s:.2f}s"
        print("tenancy_smoke,0.0,ok")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="reduced scales for CI + acceptance asserts")
    ap.add_argument("--out", default="BENCH_tenancy.json",
                    help="machine-readable results path")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(smoke=args.smoke, out_path=args.out)
