"""Benchmark harness — one entry per paper table/figure plus roofline.

Suites are DISCOVERED, not hardcoded: every module in ``benchmarks/``
exposing a callable ``run()`` registers itself (``common.py``,
``run.py``, and ``roofline.py`` are plumbing and excluded). Prints
``name,us_per_call,derived`` CSV rows. Figures map to the paper:
  fig10_*    expert-selection prediction accuracy   (paper Fig. 10)
  fig11_*    scatter-gather communication designs   (paper Fig. 11)
  fig12_*    ODS vs MIQCP vs random deployment      (paper Fig. 12)
  fig13_*    BO acquisition comparison              (paper Fig. 13)
  fig14_*    overall cost/throughput baselines      (paper Fig. 14)
  overhead_* algorithm overhead                     (paper §V-F)
  kernel_*   Pallas kernel micro-benchmarks
  roofline_* dominant roofline term per arch/shape  (EXPERIMENTS.md §Roofline)

Usage:
    PYTHONPATH=src:. python benchmarks/run.py                # all suites
    PYTHONPATH=src:. python benchmarks/run.py --list         # names only
    PYTHONPATH=src:. python benchmarks/run.py --only fig12_ods
    PYTHONPATH=src:. python benchmarks/run.py --only fig12_ods,serving_bench
"""
from __future__ import annotations

import argparse
import importlib
import pkgutil
import sys
import traceback
from pathlib import Path
from typing import Callable, Dict

# suites that are harness plumbing, not benchmarks
_EXCLUDE = {"common", "run", "roofline"}


def discover_suites() -> Dict[str, Callable[[], None]]:
    """Import every sibling module with a module-level ``run()``."""
    suites: Dict[str, Callable[[], None]] = {}
    for info in sorted(pkgutil.iter_modules([str(Path(__file__).parent)]),
                       key=lambda m: m.name):
        if info.name in _EXCLUDE or info.name.startswith("_"):
            continue
        mod = importlib.import_module(f"benchmarks.{info.name}")
        fn = getattr(mod, "run", None)
        if callable(fn):
            suites[info.name] = fn
    return suites


def roofline_summary() -> None:
    """Roofline summary (reads experiments/dryrun; skip gracefully)."""
    from benchmarks import roofline
    rows = roofline.load_all()
    for r in rows:
        if r["mesh"] == "single":
            dom = r["dominant"]
            print(f"roofline_{r['arch']}_{r['shape']},"
                  f"{r[dom + '_s'] * 1e6:.1f},dominant={dom}")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--only", default="",
                    help="comma-separated suite names to run (default: all)")
    ap.add_argument("--list", action="store_true",
                    help="print discovered suite names and exit")
    args = ap.parse_args(argv)

    suites = discover_suites()
    if args.list:
        for name in suites:
            print(name)
        return
    if args.only:
        wanted = [s.strip() for s in args.only.split(",") if s.strip()]
        unknown = [w for w in wanted if w not in suites]
        if unknown:
            raise SystemExit(
                f"unknown suite(s) {unknown}; available: {sorted(suites)}")
        suites = {name: suites[name] for name in wanted}

    print("name,us_per_call,derived")
    failures = []
    for name, fn in suites.items():
        try:
            fn()
        except Exception:            # noqa: BLE001
            failures.append(name)
            traceback.print_exc()
    if not args.only:
        try:
            roofline_summary()
        except Exception:            # noqa: BLE001
            traceback.print_exc()
    if failures:
        print(f"FAILED suites: {failures}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
