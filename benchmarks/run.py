"""Benchmark harness — one entry per paper table/figure plus roofline.

Prints ``name,us_per_call,derived`` CSV rows. Figures map to the paper:
  fig10_*    expert-selection prediction accuracy   (paper Fig. 10)
  fig11_*    scatter-gather communication designs   (paper Fig. 11)
  fig12_*    ODS vs MIQCP vs random deployment      (paper Fig. 12)
  fig13_*    BO acquisition comparison              (paper Fig. 13)
  fig14_*    overall cost/throughput baselines      (paper Fig. 14)
  overhead_* algorithm overhead                     (paper §V-F)
  kernel_*   Pallas kernel micro-benchmarks
  roofline_* dominant roofline term per arch/shape  (EXPERIMENTS.md §Roofline)
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (fig10_prediction, fig11_comm, fig12_ods,
                            fig13_bo, fig14_overall, kernels_bench,
                            overhead, serving_bench)
    suites = [
        ("fig11_comm", fig11_comm.run),
        ("fig12_ods", fig12_ods.run),
        ("kernels", kernels_bench.run),
        ("overhead", overhead.run),
        ("fig10_prediction", fig10_prediction.run),
        ("fig13_bo", fig13_bo.run),
        ("fig14_overall", fig14_overall.run),
        ("serving", serving_bench.run),
    ]
    print("name,us_per_call,derived")
    failures = []
    for name, fn in suites:
        try:
            fn()
        except Exception:            # noqa: BLE001
            failures.append(name)
            traceback.print_exc()
    # roofline summary (reads experiments/dryrun; skip gracefully if absent)
    try:
        from benchmarks import roofline
        rows = roofline.load_all()
        for r in rows:
            if r["mesh"] == "single":
                dom = r["dominant"]
                print(f"roofline_{r['arch']}_{r['shape']},"
                      f"{r[dom + '_s'] * 1e6:.1f},dominant={dom}")
    except Exception:                # noqa: BLE001
        traceback.print_exc()
    if failures:
        print(f"FAILED suites: {failures}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
